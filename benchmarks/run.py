"""Benchmark orchestrator — one module per paper table/figure.

Prints ``benchmark,key=value,...`` lines plus a final CHECKS summary
validating the paper's claims. Roofline extraction (which needs the
512-device placeholder env) lives in benchmarks/bench_roofline.py as its own
entry point.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

import numpy as np

BENCHES = [
    ("fig4_linear_convergence", "benchmarks.bench_linear_convergence"),
    ("fig5_bandwidth_model", "benchmarks.bench_bandwidth_model"),
    ("fig6_minibatch", "benchmarks.bench_minibatch"),
    ("fig7a_fig8_optimal_quant", "benchmarks.bench_optimal_quant"),
    ("fig7b_dl_quant", "benchmarks.bench_dl_quant"),
    ("fig9_chebyshev_negative", "benchmarks.bench_chebyshev"),
    ("fig12_refetch", "benchmarks.bench_refetch"),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced datasets/epochs (CI mode)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    all_checks = []
    for name, module in BENCHES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        mod = importlib.import_module(module)
        rows = mod.run(quick=args.quick)
        dt = time.time() - t0
        for row in rows:
            line = ",".join(f"{k}={v}" for k, v in row.items())
            print(f"{name},{line}")
            for k, v in row.items():
                if isinstance(v, (bool, np.bool_)):
                    all_checks.append((f"{name}/{k}", bool(v)))
        print(f"{name},_timing,seconds={dt:.1f}")
    print()
    n_pass = sum(1 for _, v in all_checks if v)
    for label, v in all_checks:
        print(f"CHECK {'PASS' if v else 'FAIL'}: {label}")
    print(f"\n{n_pass}/{len(all_checks)} paper-claim checks passed")
    return 0 if n_pass == len(all_checks) else 1


if __name__ == "__main__":
    sys.exit(main())
