"""Fig. 4 / App. J — linear regression + LS-SVM with end-to-end low precision.

Paper claims validated:
  (1) double sampling at 5–6 bits converges to the fp32 solution at a
      comparable rate (linreg + LS-SVM);
  (2) naive (biased) quantization converges to a worse solution at low bits;
  (3) end-to-end (samples+model+gradient) quantization adds only a small
      constant variance factor.
"""
from __future__ import annotations

from repro.core.linear import Precision, eval_accuracy, make_dataset, train_linear


def run(quick: bool = False):
    rows = []
    epochs = 8 if quick else 15
    n_train = 2000 if quick else 10_000
    for ds_name, model in (("synthetic100", "linreg"), ("cod-rna", "lssvm")):
        ds = make_dataset(ds_name, n_train=n_train, n_test=2000)
        runs = {
            "fp32": Precision("full"),
            "double_6b": Precision("double", bits_sample=6),
            "double_2b": Precision("double", bits_sample=2),
            "naive_2b": Precision("naive", bits_sample=2),
            "e2e_6b_8b_8b": Precision("e2e", bits_sample=6, bits_model=8,
                                      bits_grad=8),
        }
        losses = {}
        for name, prec in runs.items():
            r = train_linear(ds, prec, model=model, epochs=epochs, lr=0.3,
                             ridge_c=1e-3)
            losses[name] = r.losses
            rows.append({
                "dataset": ds_name, "model": model, "mode": name,
                "final_loss": float(r.losses[-1]),
                "acc": eval_accuracy(ds, r.x) if model == "lssvm" else None,
            })
        fp32 = losses["fp32"][-1]
        checks = {
            "double6_matches_fp32": losses["double_6b"][-1] < fp32 * 1.15 + 1e-4,
            "e2e_converges": losses["e2e_6b_8b_8b"][-1] < fp32 * 1.4 + 1e-4,
        }
        if model == "linreg":
            # the App. B.1 bias D_a·x scales with 1/s² — visible at 2 bits
            # (s=3 intervals); on ±1-label classification the biased minimum
            # can still classify equally (informational there)
            checks["naive2_worse_than_double2"] = bool(
                losses["naive_2b"][-1] > losses["double_2b"][-1] * 1.02)
        rows.append({"dataset": ds_name, "model": model, "mode": "CHECKS",
                     **checks})
    return rows


def main():
    for row in run():
        print(",".join(f"{k}={v}" for k, v in row.items()))


if __name__ == "__main__":
    main()
