"""Fig. 12 / App. G.4 — the ℓ1-refetching heuristic for SVM.

Paper claim: at 8-bit quantization, <~6% of samples need refetching at full
precision, and the refetch fraction falls as bits increase.
"""
from __future__ import annotations

from repro.core.linear import Precision, eval_accuracy, make_dataset, train_linear


def run(quick: bool = False):
    rows = []
    ds = make_dataset("cod-rna", n_train=3000 if quick else 10_000, n_test=5000)
    fracs = {}
    for bits in (6, 8):
        r = train_linear(ds, Precision("double", bits_sample=bits), model="svm",
                         epochs=4 if quick else 8, lr=0.2, reg="ball",
                         refetch="l1")
        fracs[bits] = float(r.extra["refetch_frac"][-1])
        rows.append({"bits": bits, "refetch_frac": fracs[bits],
                     "test_acc": eval_accuracy(ds, r.x)})
    rows.append({"bits": "CHECKS",
                 "more_bits_fewer_refetches": fracs[8] <= fracs[6] + 0.02,
                 "refetch_8b_small": fracs[8] < 0.25})
    return rows


def main():
    for row in run():
        print(",".join(f"{k}={v}" for k, v in row.items()))


if __name__ == "__main__":
    main()
