"""Bit-plane storage benchmark: bytes-vs-bits linearity, slice identity, and
a bursty-trace replay of the precision autoscaler.

Three claims, all deterministic (no wall-clock in any CHECK — CI runs this
on CPU where timing is interpret-mode noise):

* **bytes streamed are linear in served bits** — ``slice_planes(k)`` is a
  view of the top-k magnitude planes, so a k-bit decode streams exactly
  ``(k+1)/(B+1)`` of the stored code bytes (sign plane + k magnitude
  planes; MLWeaving's any-precision claim). Checked exactly from
  ``QTensor.nbytes`` across k = 1..8.
* **slicing is lossless re-quantization** — the top-k planes of an 8-bit
  encode are bit-for-bit the direct k-bit encode (truncation nests), so the
  runtime dial serves the *same* model a k-bit ship artifact would.
* **the autoscaler holds an admission SLO a fixed precision can't** — a
  bursty request trace replayed on a virtual clock through the real
  :class:`repro.serve.PrecisionAutoscaler`, with per-step service time
  proportional to the planes streamed (the byte model above: d(k) =
  base + β·(k+1)). Fixed 8-bit serving blows the admission-latency SLO on
  the burst; the governor sheds bits, holds the SLO, and restores full
  precision once the burst passes.
"""
from __future__ import annotations

from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import quant
from repro.quant import QScheme
from repro.serve import AutoscalerConfig, PrecisionAutoscaler

STORE_BITS = 8

# virtual-clock service-time model: decode streams (k+1) planes, and decode
# is weight-bandwidth-bound, so step time is affine in planes streamed
BASE_MS, PER_PLANE_MS = 0.5, 0.5


def _service_ms(bits: int) -> float:
    return BASE_MS + PER_PLANE_MS * (bits + 1)


def _replay(arrivals_s, *, autoscaler=None, fixed_bits: int = STORE_BITS):
    """Single-server replay on a virtual clock: admit → observe → serve one.

    Returns (admission waits in ms, bits used per step). With ``autoscaler``
    the governor is ticked once per step with the head-of-line wait and
    queue depth — the same signals ``ServeEngine.step`` feeds it.
    """
    t, i = 0.0, 0
    queue: deque[float] = deque()
    waits_ms, bits_trace = [], []
    while i < len(arrivals_s) or queue:
        while i < len(arrivals_s) and arrivals_s[i] <= t:
            queue.append(arrivals_s[i])
            i += 1
        if not queue:
            t = arrivals_s[i]
            continue
        wait_ms = (t - queue[0]) * 1e3
        if autoscaler is not None:
            bits = autoscaler.observe(admit_wait_ms=wait_ms,
                                      queue_depth=len(queue), now=t)
        else:
            bits = fixed_bits
        waits_ms.append((t - queue.popleft()) * 1e3)
        bits_trace.append(bits)
        t += _service_ms(bits) * 1e-3
    return waits_ms, bits_trace


def run(quick: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (128, 256) if quick else (512, 1024)) * 0.1
    q8 = quant.encode(w, QScheme.bitplane(STORE_BITS))

    # -- bytes streamed vs served bits: exact (k+1)-plane linearity ---------
    per_plane = q8.codes.size * 4 // (STORE_BITS + 1)
    scale_b = q8.nbytes - q8.codes.size * 4
    linear = True
    for k in range(1, STORE_BITS + 1):
        qk = q8.slice_planes(k)
        linear &= qk.nbytes == (k + 1) * per_plane + scale_b
    rows.append({
        "case": "bytes_vs_bits",
        "plane_bytes": per_plane,
        "bytes_1bit": q8.slice_planes(1).nbytes,
        "bytes_8bit": q8.nbytes,
        "bytes_linear_in_planes": bool(linear),
    })

    # -- slice identity: top-k planes ≡ direct k-bit encode -----------------
    ident = True
    for k in (1, 2, 4):
        qk, direct = q8.slice_planes(k), quant.encode(w, QScheme.bitplane(k))
        ident &= bool(jnp.array_equal(qk.codes, direct.codes))
        ident &= bool(jnp.array_equal(qk.decode(), direct.decode()))
    rows.append({"case": "slice_identity",
                 "slice_equals_direct_encode": bool(ident)})

    # -- bursty-trace replay: governor vs fixed 8-bit on a virtual clock ----
    # 40 requests land at t=0 (the burst), then a quiet tail of 20 at 10 ms
    # spacing — long enough for the restore walk (3 rungs × patience 4) to
    # climb all the way back
    burst, tail = 40, 20
    arrivals = [0.0] * burst + [0.3 + 0.01 * j for j in range(tail)]
    slo_ms = 80.0
    cfg = AutoscalerConfig(slo_admit_ms=slo_ms, queue_high=8,
                           breach_patience=2, restore_patience=4)

    fixed_waits, _ = _replay(arrivals, fixed_bits=STORE_BITS)
    gov = PrecisionAutoscaler(cfg)
    auto_waits, bits_trace = _replay(arrivals, autoscaler=gov)

    rows.append({
        "case": "burst_replay",
        "requests": len(arrivals),
        "slo_admit_ms": slo_ms,
        "fixed8_max_wait_ms": round(max(fixed_waits), 1),
        "auto_max_wait_ms": round(max(auto_waits), 1),
        "min_bits": min(bits_trace),
        "final_bits": gov.bits,
        "rung_moves": len(gov.decisions),
        "fixed8_violates_slo": bool(max(fixed_waits) > slo_ms),
        "autoscaler_holds_slo": bool(max(auto_waits) <= slo_ms),
        "bits_restored_after_burst": bool(gov.bits == STORE_BITS
                                          and min(bits_trace) < STORE_BITS),
    })
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
