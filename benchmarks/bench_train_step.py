"""Train-step economics: step wall-clock, gradient wire bytes, and the
fused-vs-unfused quantized-AdamW HBM sweep.

Three accounting views plus a wall-clock probe:

* **Gradient wire bytes** — the C3 channel's all-reduce payload, counted
  from ``QTensor.nbytes`` on the actually-compressed gradient tree (int8
  codes + per-tensor scales) against the dense f32/bf16 payload.
* **Optimizer-sweep HBM bytes** — deterministic byte model of the per-step
  m/v sweep: the unfused jnp path materializes both fp32 moment tensors in
  HBM twice (decode out, re-encode in); the fused kernel
  (kernels/quant_adamw.py) recomputes them per VMEM tile and only ever
  streams g, int8 codes, rand bits and the master.
* **Wall-clock** — a short supervisor-free Trainer run (steady-state step
  time after compile) and the fused ``ops.quant_adamw_update`` vs the
  jnp decode→update→re-encode path. (On CPU the Pallas kernels run in
  interpret mode, so absolute times are correctness-lane numbers; the bytes
  model is the hardware claim.)
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.optim import adamw
from repro.precision import gradcomp
from repro.quant import QTensor, tree_nbytes


def opt_sweep_bytes(n: int, bits: int = 8, fused: bool = False) -> int:
    """HBM bytes per optimizer step for n quantized-moment parameters.

    unfused (three logical sweeps): decode codes→fp32 m/v, update (g +
    master r/w + fp32 m/v r/w), re-encode (absmax read + quantize read +
    rand + codes write). fused: pass 1 reads g+codes for the scales, pass 2
    reads them again plus rand and the master — fp32 m/v never touch HBM.
    """
    code = 2 * (n * bits // 8)          # both moment code planes
    f32 = 4 * n
    if fused:
        pass1 = f32 + code              # g + codes → per-tile absmax
        pass2 = f32 + code + f32 + 2 * f32 + code   # + rand + master r/w
        return pass1 + pass2
    decode = code + 2 * f32                          # codes in, fp32 m/v out
    update = 2 * f32 + f32 + 2 * f32 + 2 * f32       # m/v + g + master r/w + m/v out
    encode = 2 * f32 + 2 * f32 + f32 + code          # absmax + quantize + rand + codes
    return decode + update + encode


def grad_wire_bytes(grads, bits: int, key) -> tuple[int, int]:
    """(compressed, dense-f32) bytes of the gradient all-reduce payload."""
    comp, _ = gradcomp.compress_tree(grads, bits, key)
    dense = sum(4 * int(np.prod(g.shape)) for g in jax.tree.leaves(grads))
    return tree_nbytes(comp), dense


def _time(fn, reps: int) -> float:
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e3   # ms


def make_calibrator():
    """A fixed fp32 matmul-chain probe — the machine-speed yardstick the
    bench regression gate normalizes step time against, so a committed
    baseline from one machine transfers to another. The returned sampler is
    INTERLEAVED with the timed train steps (one probe per step) so both
    sides of the step/calib ratio see the same load regime; the gate takes
    the min of each (best-case samples cancel machine speed and transient
    load alike)."""
    a = jnp.ones((768, 768), jnp.float32)
    f = jax.jit(lambda a: (a @ a) @ a)
    f(a).block_until_ready()

    def sample() -> float:
        t0 = time.perf_counter()
        jax.block_until_ready(f(a))
        return (time.perf_counter() - t0) * 1e3

    return sample


def calibration_ms(reps: int = 15) -> float:
    sample = make_calibrator()
    return float(np.min([sample() for _ in range(reps)]))


def run(quick: bool = False):
    from repro.launch.train import make_trainer
    from repro.quant import PrecisionPlan

    rows = []
    key = jax.random.PRNGKey(0)
    # ≥ 7 timed steps even in smoke mode: the regression gate keys off the
    # min step time, and a 3-sample min is still dispatch-noise-dominated
    steps = 8 if quick else 10

    calib = make_calibrator()
    calib_pre = float(np.min([calib() for _ in range(5)]))
    # -- end-to-end trainer step time (ref backend, steady state) -----------
    with registry.using("ref"):
        tr = make_trainer("musicgen-medium", batch=2, seq=16, steps=steps,
                          precision=PrecisionPlan(grad_bits=8), moment_bits=8,
                          log_every=10_000)
        state = tr.init_state()
        tr.stream.skip_to(state.cursor)
        state, _ = tr.step(state, tr.stream.next_batch())   # compile
        times, calibs = [], []
        for _ in range(steps - 1):
            t0 = time.perf_counter()
            state, metrics = tr.step(state, tr.stream.next_batch())
            jax.block_until_ready(metrics["loss"])
            times.append(time.perf_counter() - t0)
            calibs.append(calib())        # probe under the SAME load regime
        grads_like = state.params
        n_params = sum(int(np.prod(p.shape))
                       for p in jax.tree.leaves(state.params))
    # calib_ms (interleaved min) normalizes the step for the gate;
    # calib_ms_end vs calib_ms is the gate's machine-jitter guard (the
    # byte CHECKs gate unconditionally either way)
    rows.append({"case": "trainer_g8m8", "steps": steps,
                 "step_ms": round(float(np.mean(times)) * 1e3, 2),
                 "step_ms_min": round(float(np.min(times)) * 1e3, 2),
                 "calib_ms": round(float(np.min(calibs)), 3),
                 "calib_ms_end": round(min(calib_pre,
                                           float(np.min(calibs))), 3),
                 "n_params": n_params})

    # -- ship weight path: codes through gather + matmul (QTensor.nbytes) ---
    from repro.precision import qat
    from repro.quant import ShipWeight

    shipped = qat.ship_quant_tree(state.params, 8, min_size=0)
    ships = [leaf for leaf in jax.tree.leaves(
        shipped, is_leaf=lambda x: isinstance(x, ShipWeight))
        if isinstance(leaf, ShipWeight)]
    ship_q = sum(s.qt.nbytes for s in ships)
    ship_bf16 = sum(2 * int(np.prod(s.qt.shape)) for s in ships)
    ratio_w = ship_q / ship_bf16 if ship_bf16 else 1.0
    rows.append({"case": "ship_weight_path", "bits": 8,
                 "code_bytes": ship_q, "bf16_bytes": ship_bf16,
                 "ratio": round(ratio_w, 3),
                 "ship_int8_le_055x": bool(ratio_w <= 0.55)})

    # -- gradient wire bytes (QTensor.nbytes vs dense f32) -------------------
    comp_bytes, dense_bytes = grad_wire_bytes(grads_like, 8, key)
    ratio = dense_bytes / comp_bytes
    rows.append({"case": "grad_wire", "bits": 8,
                 "wire_bytes": comp_bytes, "dense_bytes": dense_bytes,
                 "ratio": round(ratio, 2),
                 "wire_ratio_ge_3x": bool(ratio >= 3.0)})

    # -- optimizer sweep: byte model + wall-clock fused vs unfused ----------
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(grads_like))
    fused_b = opt_sweep_bytes(n, 8, fused=True)
    unfused_b = opt_sweep_bytes(n, 8, fused=False)
    r, c = (256, 512) if quick else (1024, 2048)
    master = jax.random.normal(key, (r, c))
    g = jax.random.normal(jax.random.fold_in(key, 1), (r, c)) * 0.1
    sch = adamw.moment_scheme(8, 2)
    m_q = QTensor(jnp.zeros((r, c), jnp.int8), jnp.ones((c,)), sch)
    km, kv = jax.random.split(key)
    kw = dict(bits=8, b1=0.9, b2=0.95, eps=1e-8, b1c=jnp.float32(0.1),
              b2c=jnp.float32(0.05), lr=jnp.float32(1e-3),
              clip=jnp.float32(1.0), finite=jnp.bool_(True), wd=0.1)
    reps = 2 if quick else 5
    t_ref = _time(lambda: jax.block_until_ready(
        registry.get("ref").quant_adamw_update(
            master, g, m_q, m_q, km, kv, **kw)[0]), reps)
    t_fused = _time(lambda: jax.block_until_ready(
        registry.get("pallas").quant_adamw_update(
            master, g, m_q, m_q, km, kv, **kw)[0]), reps)
    opt_row = {"case": "opt_sweep", "bits": 8, "n_params": n,
               "fused_bytes": fused_b, "unfused_bytes": unfused_b,
               "bytes_saved_ratio": round(unfused_b / fused_b, 2),
               "ms_jnp": round(t_ref, 2), "ms_fused_interpret": round(t_fused, 2),
               "fused_bytes_lt_unfused": bool(fused_b < unfused_b)}
    # roofline annotation: HBM bytes of the timed fused call (the (r, c)
    # sweep, not the n-param model above) over the measured machine peak
    from repro import perf
    perf.annotate_row(opt_row, bytes_moved=opt_sweep_bytes(r * c, 8, fused=True),
                      ms=t_fused)
    rows.append(opt_row)
    # fp32-vs-int8 resident moments (the dry-run line item)
    rows.append({"case": "moment_resident", "n_params": n,
                 "int8_bytes": 2 * n, "fp32_bytes": 8 * n,
                 "int8_resident_4x_smaller": bool(8 * n >= 4 * (2 * n))})
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
