"""Fig. 5 analog — the bandwidth-bound speedup model (FPGA → roofline terms).

The paper's FPGA prototype is memory-bandwidth bound: Q4 data cuts
SampleStore traffic 8× vs fp32 and yields 6.5× end-to-end. We reproduce the
*economics*: bytes-per-sample of each wire format (including double-sampling's
+log2(k) bit overhead, §2.2), the implied bandwidth-bound speedup, and a
measured wall-clock ratio of the quantized vs fp32 SGD step on this host
(CPU is also bandwidth-bound for K≫cache matvecs, so the trend reproduces;
exact 6.5× is FPGA-specific).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import quant
from repro.core.linear import make_dataset
from repro.data.pipeline import QuantizedSampleStore
from repro.quant import QScheme


def wire_bytes(n_features: int, bits: int, double_sampling: bool) -> float:
    bits_total = bits * n_features + (1 if double_sampling else 0) * n_features
    return bits_total / 8.0


def run(quick: bool = False):
    rows = []
    n = 1000  # features — make the matvec stream-bound ("synthetic1000" preset)
    ds = make_dataset("synthetic1000", n_train=2000, n_test=128)
    store = QuantizedSampleStore.build(ds.a_train, ds.b_train, bits=4)
    fp32_bytes = 4.0 * n
    for bits in (1, 2, 4, 8):
        wb = wire_bytes(n, bits, double_sampling=True)
        rows.append({
            "format": f"Q{bits}+ds",
            "bytes_per_sample": wb,
            "bw_reduction_vs_fp32": fp32_bytes / wb,
        })
    # the analytic model, read back from an actual QTensor: quantize a batch
    # with the §2.2 pair draw and report HBM bytes straight from .nbytes
    batch = jnp.asarray(ds.a_train[:256], jnp.float32)
    col_scale = jnp.asarray(store.scale, jnp.float32)
    qt = quant.ds_pair(batch, QScheme.zipml(2**4 - 1, scaling="column",
                                            rounding="ds"),
                       jax.random.PRNGKey(0), scale=col_scale, backend="ref")
    codes_bytes = qt.nbytes - 4 * n           # minus the shared column scales
    qt_per_sample = codes_bytes / batch.shape[0]
    rows.append({
        "format": "Q4+ds_qtensor_nbytes",
        "bytes_per_sample": qt_per_sample,
        "scale_bytes_amortized": 4.0 * n / batch.shape[0],
        "bw_reduction_vs_fp32": fp32_bytes / qt_per_sample,
    })
    # wall-clock probe: fp32 step vs int8-stored step (same math, smaller reads)
    a32 = jnp.asarray(ds.a_train, jnp.float32)
    a8 = jnp.asarray(store.codes)  # int8
    scale = jnp.asarray(store.scale / store.s, jnp.float32)
    x = jnp.zeros((n,), jnp.float32)

    @jax.jit
    def step32(x, a):
        return a.T @ (a @ x - 1.0)

    @jax.jit
    def step8(x, codes):
        aq = codes.astype(jnp.float32) * scale
        return aq.T @ (aq @ x - 1.0)

    step32(x, a32).block_until_ready(); step8(x, a8).block_until_ready()
    reps = 5 if quick else 20
    t0 = time.perf_counter()
    for _ in range(reps):
        step32(x, a32).block_until_ready()
    t32 = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    for _ in range(reps):
        step8(x, a8).block_until_ready()
    t8 = (time.perf_counter() - t0) / reps
    rows.append({"format": "measured_wallclock",
                 "fp32_ms": t32 * 1e3, "int8_ms": t8 * 1e3,
                 "speedup": t32 / t8})
    rows.append({"format": "CHECKS",
                 "q4_bw_reduction_ge_6x": fp32_bytes / wire_bytes(n, 4, True) >= 6.0,
                 "qtensor_nbytes_matches_wire_model":
                     abs(qt_per_sample - wire_bytes(n, 4, True)) < 1.0})
    return rows


def main():
    for row in run():
        print(",".join(f"{k}={v}" for k, v in row.items()))


if __name__ == "__main__":
    main()
