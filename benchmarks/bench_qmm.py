"""quant_dense economics: weight-path HBM bytes, the fused quantize
epilogue's activation-pass saving, and backward-from-codes gradient parity.

The ZipML claim this bench pins: every hot matmul should move *code bytes*,
not floats, through the memory hierarchy — forward, backward, and (with the
epilogue) the activation hand-off to the next quantized consumer.

* **Weight-path bytes** — ``QTensor.nbytes`` (codes + scales, the repo's one
  byte model) vs the bf16 decode path's 2·K·N weight read.
  CHECKs: int8 ≤ 0.55×, packed int4 ≤ 0.30×.
* **Epilogue bytes** — the unfused activation hand-off writes the full-width
  y and re-reads it in the quantize pass; the fused epilogue emits the §2.2
  DS pair straight from the fp32 accumulator tile.
  CHECK: fused saves ≥ 1 full-width activation HBM pass (write + read gone).
* **Gradient parity** — dx = dy·(codes ⊙ scale)ᵀ streamed from int8 /
  packed-int4 codes (kernels/qmm.qmm_t) vs the f32 decode-path gradient.
  CHECK: relative error ≤ 1e-5 (f32-accumulation associativity only).
* Wall-clock — fused vs decode-then-einsum (on CPU the kernels run in
  interpret mode, so times are correctness-lane numbers; the bytes model is
  the hardware claim).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import quant
from repro.quant import QScheme, quant_dense, quant_dense_q


def weight_path_bytes(k: int, n: int, bits: int, packed: bool) -> dict:
    """Per-matmul weight-read bytes: QTensor.nbytes vs the bf16 decode path."""
    w = np.zeros((k, n), np.float32)
    scheme = QScheme.int_symmetric(bits, scaling="channel", channel_axis=-2,
                                   rounding="nearest", packed=packed)
    qt = quant.encode(jnp.asarray(w), scheme)
    return {"q_bytes": qt.nbytes, "bf16_bytes": 2 * k * n}


def epilogue_bytes(m: int, k: int, n: int) -> dict:
    """HBM bytes of the activation hand-off to a quantized consumer,
    derived from the ACTUAL I/O signatures of the two pipelines via
    ``jax.eval_shape`` — not an analytic identity, so a kernel change that
    starts spilling the accumulator (an extra dense output on qmm_qout)
    flips the CHECK.

    unfused: the qmm y output (f32 write) is re-read by the separate row
    ds-quantize pass. fused: qmm_qout's signature has no dense y anywhere.
    """
    from repro.kernels import ops

    def nbytes(tree):
        return sum(int(np.prod(leaf.shape)) * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(tree))

    x = jax.ShapeDtypeStruct((m, k), jnp.bfloat16)
    codes = jax.ShapeDtypeStruct((k, n), jnp.int8)
    scale = jax.ShapeDtypeStruct((1, n), jnp.float32)
    rand = jax.ShapeDtypeStruct((m, n), jnp.uint32)

    y = jax.eval_shape(lambda a, c, s: ops.quant_dense_apply(a, c, s),
                       x, codes, scale)

    def row_ds(y, rand):
        absmax = jnp.max(jnp.abs(y), axis=1, keepdims=True)
        sc = jnp.where(absmax == 0, 1.0, absmax / 127)
        t = y.astype(jnp.float32) / sc
        base = jnp.floor(t)
        u1 = (rand >> 16).astype(jnp.float32)
        u2 = (rand & 0xFFFF).astype(jnp.float32)
        c1 = jnp.clip(base + (u1 < t), -127, 127).astype(jnp.int8)
        c2 = jnp.clip(base + (u2 < t), -127, 127).astype(jnp.int8)
        return c1, c2, sc

    quant_out = jax.eval_shape(row_ds, y, rand)
    fused_out = jax.eval_shape(
        lambda a, c, s, r: ops.quant_dense_out_q(a, c, s, r, qmax=127),
        x, codes, scale, rand)

    shared_in = nbytes([x, codes, scale, rand])
    unfused = shared_in + nbytes(y) * 2 + nbytes(quant_out)  # y write + read
    fused = shared_in + nbytes(fused_out)
    return {"unfused": unfused, "fused": fused, "full_pass": nbytes(y)}


def _time(fn, reps: int) -> float:
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e3      # ms


def run(quick: bool = False):
    key = jax.random.PRNGKey(0)
    m, k, n = (64, 256, 128) if quick else (256, 1024, 512)
    reps = 2 if quick else 5
    rows = []

    x = jax.random.normal(key, (m, k)).astype(jnp.bfloat16)
    g = jax.random.normal(jax.random.fold_in(key, 1), (m, n)).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.fold_in(key, 2), (k, n)) * 0.05

    # -- weight-path HBM bytes ----------------------------------------------
    b8 = weight_path_bytes(k, n, 8, packed=False)
    b4 = weight_path_bytes(k, n, 4, packed=True)
    r8 = b8["q_bytes"] / b8["bf16_bytes"]
    r4 = b4["q_bytes"] / b4["bf16_bytes"]
    rows.append({"case": "weight_path", "K": k, "N": n,
                 "int8_bytes": b8["q_bytes"], "int4_bytes": b4["q_bytes"],
                 "bf16_bytes": b8["bf16_bytes"],
                 "int8_ratio": round(r8, 3), "int4_ratio": round(r4, 3),
                 "int8_le_055x": bool(r8 <= 0.55),
                 "int4_le_030x": bool(r4 <= 0.30)})

    # -- backward-from-codes gradient parity --------------------------------
    # measured at the f32 op level (the model then casts BOTH paths to the
    # activation dtype identically), against the f32 decode-path gradient
    from repro.kernels import registry
    pallas = registry.get("pallas")
    for bits, packed in ((8, False), (4, True)):
        scheme = QScheme.int_symmetric(bits, scaling="channel",
                                       rounding="nearest", channel_axis=-2,
                                       packed=packed)
        qt = quant.encode(w, scheme)
        wd = qt.decode()                                # f32 decode path
        dx_ref = jnp.einsum("...n,kn->...k", g.astype(jnp.float32), wd)
        dx = pallas.quant_dense(g, qt, transpose=True)
        rel = float(jnp.abs(dx - dx_ref).max() / jnp.abs(dx_ref).max())
        y_ref = jnp.einsum("...k,kn->...n", x.astype(jnp.float32), wd)
        y = pallas.quant_dense(x, qt)
        fwd_rel = float(jnp.abs(y - y_ref).max() / jnp.abs(y_ref).max())
        rows.append({"case": f"grad_parity_int{bits}",
                     "storage": "packed-int4" if packed else "int8",
                     "fwd_rel": float(f"{fwd_rel:.2e}"),
                     "dx_rel": float(f"{rel:.2e}"),
                     "grad_from_codes_le_1e5": bool(rel <= 1e-5)})

    # -- fused quantize epilogue --------------------------------------------
    eb = epilogue_bytes(m, k, n)
    saved = eb["unfused"] - eb["fused"]
    qt8 = quant.encode(w, QScheme.int_symmetric(8, scaling="channel",
                                                rounding="nearest",
                                                channel_axis=-2))
    fused = quant_dense_q(x, qt8, key, bits=8, backend="pallas")
    # unfused reference with identical rounding bits: qmm → astype → ds rows
    rand = jax.random.bits(key, (m, n), jnp.uint32)
    yb = quant_dense(x, qt8, backend="pallas").astype(x.dtype).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(yb), axis=1, keepdims=True)
    sc = jnp.where(absmax == 0, 1.0, absmax / 127)
    t = yb / sc
    base = jnp.floor(t)
    u1 = (rand >> 16).astype(jnp.float32) / (1 << 16)
    u2 = (rand & 0xFFFF).astype(jnp.float32) / (1 << 16)
    c1 = jnp.clip(base + (u1 < (t - base)), -127, 127).astype(jnp.int8)
    c2 = jnp.clip(base + (u2 < (t - base)), -127, 127).astype(jnp.int8)
    exact = bool((fused.codes == c1).all()) and bool((fused.codes2 == c2).all())
    rows.append({"case": "epilogue", "M": m, "N": n,
                 "unfused_bytes": eb["unfused"], "fused_bytes": eb["fused"],
                 "full_pass_bytes": eb["full_pass"],
                 "fused_vs_unfused_codes_exact": exact,
                 "epilogue_saves_ge_1_act_pass":
                     bool(saved >= eb["full_pass"])})

    # -- wall-clock (interpret-mode correctness numbers on CPU) -------------
    qt4 = quant.encode(w, QScheme.int_symmetric(4, scaling="channel",
                                                rounding="nearest",
                                                channel_axis=-2, packed=True))
    t_ref = _time(lambda: jax.block_until_ready(
        quant_dense(x, qt8, backend="ref")), reps)
    t_p8 = _time(lambda: jax.block_until_ready(
        quant_dense(x, qt8, backend="pallas")), reps)
    t_p4 = _time(lambda: jax.block_until_ready(
        quant_dense(x, qt4, backend="pallas")), reps)
    wall = {"case": "wallclock", "ms_ref_decode": round(t_ref, 2),
            "ms_pallas_int8": round(t_p8, 2),
            "ms_pallas_int4": round(t_p4, 2)}
    rows.append(wall)

    # -- roofline: measured peaks + per-(op, dtype, bucket) autotune rows ---
    # peaks come from the ERT-style probe (repro/perf/probe.py), cached per
    # hardware fingerprint; tune() sweeps block shapes for every Pallas
    # kernel, persists winners to the autotune cache, and annotates each row
    # with bytes-moved / achieved GB/s / fraction-of-roofline. The
    # `autotune_no_worse` booleans become CHECKs: the hand-picked default is
    # always candidate 0 of the same sweep, so the winner can't lose to it.
    from repro import perf
    peaks = perf.get_peaks(smoke=quick)
    # int8 forward stream: x (bf16) + codes + scales in, f32 y out
    perf.annotate_row(wall, bytes_moved=2 * m * k + k * n + 4 * n + 4 * m * n,
                      ms=t_p8, peaks=peaks)
    rows.append({"case": "roofline_peaks", "fingerprint": peaks["key"],
                 "peak_gbps": peaks["peak_gbps"],
                 "peak_gflops": peaks["peak_gflops"],
                 # a string, not a bool: probe mode must never become a
                 # gated CHECK (a cached full probe would flip it)
                 "probe_mode": "smoke" if peaks["smoke"] else "full"})
    rows.extend(perf.tune(smoke=quick, peaks=peaks))
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
