"""Fig. 7(b) — Optimal5 vs XNOR5: optimal model quantization for deep nets.

The paper trains Caffe's CIFAR-10 CNN with 5-level weight quantization:
uniform levels (XNOR-Net's multi-bit scheme) vs the variance-optimal levels
(C4+C5). CIFAR-10 is unavailable offline; we train a small MLP on a synthetic
32×32×3 image-classification proxy with QAT fake-quant in both schemes and
compare training losses — the claim is the *ordering*, which is driven by the
weight distribution being bell-shaped rather than uniform.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import optimal


def _make_data(n=2048, seed=0, classes=20):
    # hard enough that 5-level weight quantization error is the bottleneck
    rng = np.random.default_rng(seed)
    protos = rng.normal(0, 1, (classes, 32 * 32 * 3))
    y = rng.integers(0, classes, n)
    x = protos[y] + rng.normal(0, 4.0, (n, 32 * 32 * 3))
    return jnp.asarray(x, jnp.float32), jnp.asarray(y)


def _init(key, d_in=3072, width=128, classes=20):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(k1, (d_in, width)) * d_in**-0.5,
        "w2": jax.random.normal(k2, (width, width)) * width**-0.5,
        "w3": jax.random.normal(k3, (width, classes)) * width**-0.5,
    }


def _levels_for(w, scheme: str, n_levels: int = 5):
    hi = float(jnp.max(jnp.abs(w)))
    if scheme == "uniform":
        return jnp.linspace(-hi, hi, n_levels)
    lv = optimal.fit_levels(np.asarray(w).ravel(), n_levels - 1, symmetric=True)
    # symmetric fit may give n_levels±1; resample to exactly n_levels by DP
    if len(lv) != n_levels:
        z = (np.asarray(w).ravel() + hi) / (2 * hi)
        lv01 = optimal.optimal_levels_discretized(z, n_levels - 1, M=128)
        lv = lv01 * 2 * hi - hi
    return jnp.asarray(lv, jnp.float32)


def _quantize_to(w, levels):
    d = jnp.abs(w[..., None] - levels)
    return levels[jnp.argmin(d, axis=-1)]


def _loss(params, x, y, scheme, refit_levels):
    def q(w, name):
        return w + jax.lax.stop_gradient(_quantize_to(w, refit_levels[name]) - w)
    h = jax.nn.relu(x @ q(params["w1"], "w1"))
    h = jax.nn.relu(h @ q(params["w2"], "w2"))
    logits = h @ q(params["w3"], "w3")
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=1)[:, 0]
    return jnp.mean(logz - gold)


def train(scheme: str, steps=300, lr=0.1, seed=0):
    x, y = _make_data()
    params = _init(jax.random.PRNGKey(seed))
    losses = []
    grad_fn = jax.jit(jax.value_and_grad(_loss), static_argnames=("scheme",))
    for t in range(steps):
        # refit levels every 25 steps (the DP runs off the training hot path)
        if t % 25 == 0:
            refit = {k: _levels_for(w, scheme) for k, w in params.items()}
        idx = np.random.default_rng(t).integers(0, x.shape[0], 128)
        lv, g = grad_fn(params, x[idx], y[idx], scheme, refit)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        losses.append(float(lv))
    return np.asarray(losses)


def run(quick: bool = False):
    steps = 120 if quick else 300
    uni = train("uniform", steps=steps)
    opt = train("optimal", steps=steps)
    # An over-parameterized net eventually ADAPTS its weights to either level
    # grid (losses both → ~0), so the discriminating regime is the early
    # phase, before adaptation — matching the paper's "converges to lower
    # training loss faster" framing for Fig. 7(b). Average over seeds.
    early = slice(15, 80)
    uni_e = [train("uniform", steps=90, seed=sd)[early].mean() for sd in (0, 1, 2)]
    opt_e = [train("optimal", steps=90, seed=sd)[early].mean() for sd in (0, 1, 2)]
    tail = slice(-20, None)
    return [{
        "mode": "XNOR5-uniform", "early_loss": float(np.mean(uni_e)),
        "final_loss": float(uni[tail].mean()),
    }, {
        "mode": "Optimal5", "early_loss": float(np.mean(opt_e)),
        "final_loss": float(opt[tail].mean()),
    }, {
        "mode": "CHECKS",
        "optimal5_beats_xnor5": float(np.mean(opt_e)) < float(np.mean(uni_e)),
    }]


def main():
    for row in run():
        print(",".join(f"{k}={v}" for k, v in row.items()))


if __name__ == "__main__":
    main()
