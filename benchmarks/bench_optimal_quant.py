"""Fig. 7(a) + Fig. 8 — data-optimal quantization vs uniform.

Paper claims validated:
  (1) optimal 3-bit ≈ uniform 5-bit convergence ("save 1.7× bits");
  (2) at equal bits, optimal levels converge faster / to lower loss;
  (3) quantization variance (the thing the DP minimizes) is strictly lower
      under optimal levels, per feature.
"""
from __future__ import annotations

import numpy as np

from repro.core import optimal
from repro.core.linear import Precision, make_dataset, train_linear


def variance_gain(ds, bits: int) -> float:
    """Mean per-feature MV(uniform)/MV(optimal) — the paper's Fig. 3/7 object."""
    s = 2**bits - 1
    scale = np.maximum(np.abs(ds.a_train).max(axis=0), 1e-12)
    z = np.abs(ds.a_train) / scale
    gains = []
    for f in range(min(ds.n_features, 32)):
        mv_u = optimal.mean_variance(z[:, f], optimal.uniform_levels(s))
        lv = optimal.optimal_levels_discretized(z[:, f], s, M=128)
        mv_o = optimal.mean_variance(z[:, f], lv)
        if mv_o > 0:
            gains.append(mv_u / mv_o)
    return float(np.mean(gains))


def run(quick: bool = False):
    rows = []
    epochs = 8 if quick else 15
    for ds_name in ("yearprediction", "synthetic100"):
        ds = make_dataset(ds_name, n_train=2000 if quick else 10_000, n_test=2000)
        results = {}
        for bits in (3, 5):
            for opt in (False, True):
                prec = Precision("double", bits_sample=bits, use_optimal_levels=opt)
                r = train_linear(ds, prec, epochs=epochs, lr=0.3)
                key = f"{'opt' if opt else 'uni'}{bits}"
                results[key] = float(r.losses[-1])
                rows.append({"dataset": ds_name, "mode": key,
                             "final_loss": results[key]})
        full = float(train_linear(ds, Precision("full"), epochs=epochs,
                                  lr=0.3).losses[-1])
        rows.append({
            "dataset": ds_name, "mode": "CHECKS",
            "opt3_close_to_uni5": results["opt3"] <= results["uni5"] * 1.25,
            "opt_beats_uni_at_3b": results["opt3"] <= results["uni3"] * 1.02,
            "uni5_near_full": results["uni5"] < full * 1.3 + 1e-4,
            "variance_gain_3b": variance_gain(ds, 3),
        })
    return rows


def main():
    for row in run():
        print(",".join(f"{k}={v}" for k, v in row.items()))


if __name__ == "__main__":
    main()
