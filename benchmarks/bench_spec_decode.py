"""Self-speculative decoding benchmark: int4/int2 draft + full-precision
verify through the serving engine, across KV precisions.

What it measures:

* **token identity** — at every (kv_bits × draft_bits) combination the
  speculative engine's greedy output must equal vanilla decode token for
  token. This is the engine's core guarantee (accepted rows are minted by
  the verify pass's own full-precision write-then-attend), so it is a
  CHECK, not a tolerance.
* **acceptance rate** — fraction of drafted tokens the verify accepted,
  per combination. Random init weights give a low-but-nonzero rate (the
  low-bit slice of a random matrix is a poor predictor); it is reported as
  data, the speedup claim does not ride on it.
* **modeled speedup on an acceptance-friendly model** — the
  ``top4_planes`` case zeroes every magnitude plane below the top 4, so
  the int4 ``slice_planes`` draft decodes *identically* to the full
  artifact: acceptance is exactly 1.0 by construction (the self-drafting
  regime ZipML's bit-plane storage makes free for models whose low planes
  carry little signal). Decode is weight-bandwidth-bound (§2.2 / fig 5),
  so cost is modeled in streamed weight bytes: a draft step costs
  ``c_d = draft_nbytes / full_nbytes`` of a full step (QTensor.nbytes on
  the sliced vs full tree) and one window commits ``1 + rate·k`` tokens
  for ``k·c_d + 1`` full-step equivalents. The CHECK: modeled speedup ≥
  1.3× vanilla on the shared-system-prompt trace. Wall-clock tok/s is
  reported as data only — on the CPU CI runner the reduced model is
  compute-bound, so bytes are the hardware claim (same convention as
  bench_serve_engine).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.bench_serve_engine import make_shared_trace
from repro import configs
from repro.models import transformer as T
from repro.precision.qat import quantize_param_tree
from repro.quant import PrecisionPlan, QTensor
from repro.serve import ServeEngine

ARCH = "qwen2.5-14b"
K = 3                                         # draft tokens per window
WEIGHT_BITS = 8


def _is_qt(x):
    return isinstance(x, QTensor)


def _bitplane_bytes(tree, bits: int | None = None) -> int:
    """QTensor.nbytes over the tree's bitplane leaves, optionally through
    the ``slice_planes(bits)`` view the draft streams."""
    total = 0
    for leaf in jax.tree.leaves(tree, is_leaf=_is_qt):
        if _is_qt(leaf) and leaf.scheme.layout == "bitplane":
            total += (leaf if bits is None else leaf.slice_planes(bits)).nbytes
    return total


def _zero_low_planes(tree, keep_bits: int):
    """Zero every magnitude plane below the top ``keep_bits`` (plane axis:
    sign, then MSB→LSB), making ``slice_planes(keep_bits)`` decode equal to
    the full artifact — the acceptance-1.0 self-draft regime."""
    def f(leaf):
        if _is_qt(leaf) and leaf.scheme.layout == "bitplane":
            return QTensor(leaf.codes.at[..., keep_bits + 1:, :, :].set(0),
                           leaf.scale, leaf.scheme)
        return leaf

    return jax.tree.map(f, tree, is_leaf=_is_qt)


def run(quick: bool = False):
    n_requests = 16 if quick else 32
    max_new = 8 if quick else 12
    page, sys_pages = 8, 4
    cfg = configs.get_reduced(ARCH)
    params = quantize_param_tree(T.init_params(jax.random.PRNGKey(0), cfg),
                                 bits=WEIGHT_BITS, layout="bitplane")

    def trace():
        return make_shared_trace(n_requests, cfg.vocab_size, page_size=page,
                                 sys_pages=sys_pages, max_new=max_new)

    def engine(p, kv_bits, **kw):
        return ServeEngine(p, cfg, plan=PrecisionPlan(kv_bits=kv_bits),
                           max_slots=4, page_size=page, max_seq_len=64, **kw)

    def identical(a, b):
        return bool(all(np.array_equal(a[rid].tokens, b[rid].tokens)
                        for rid in a))

    rows = []
    # -- token identity + measured acceptance, every kv x draft combination -
    for kv_bits in (0, 8, 4):
        kv_name = "bf16" if kv_bits == 0 else f"int{kv_bits}"
        van = engine(params, kv_bits)
        van_out = van.run(trace())
        for draft_bits in (4, 2):
            spec = engine(params, kv_bits, spec_decode=K,
                          draft_bits=draft_bits)
            out = spec.run(trace())
            spec.allocator.check_leaks(0)
            assert spec.stats["spec_steps"] > 0
            rows.append({
                "case": f"kv_{kv_name}_draft{draft_bits}",
                "requests": n_requests,
                "k": K,
                "spec_windows": spec.stats["spec_steps"],
                "acceptance_rate": round(spec.acceptance_rate(), 3),
                "tok_s_vanilla": round(van.throughput(), 1),
                "tok_s_spec": round(spec.throughput(), 1),
                "spec_token_identical": identical(van_out, out),
            })

    # -- acceptance-friendly self-draft: modeled >= 1.3x ---------------------
    top4 = _zero_low_planes(params, 4)
    van = engine(top4, 8)
    van_out = van.run(trace())
    spec = engine(top4, 8, spec_decode=K, draft_bits=4)
    out = spec.run(trace())
    spec.allocator.check_leaks(0)
    rate = spec.acceptance_rate()
    c_d = _bitplane_bytes(params, 4) / _bitplane_bytes(params)
    tokens_per_window = 1 + rate * K
    modeled_speedup = tokens_per_window / (K * c_d + 1)
    rows.append({
        "case": "top4_planes_selfdraft",
        "requests": n_requests,
        "k": K,
        "spec_windows": spec.stats["spec_steps"],
        "acceptance_rate": round(rate, 3),
        "acceptance_is_full": bool(rate >= 0.999),
        "draft_weight_byte_ratio": round(c_d, 3),
        "modeled_tokens_per_window": round(tokens_per_window, 2),
        "modeled_speedup_vs_vanilla": round(modeled_speedup, 2),
        "tok_s_vanilla": round(van.throughput(), 1),
        "tok_s_spec": round(spec.throughput(), 1),
        "spec_token_identical": identical(van_out, out),
        "modeled_speedup_ge_1_3x": bool(modeled_speedup >= 1.3),
    })
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
