"""Fig. 6 — impact of mini-batch size on quantized training.

Paper claim: the input-quantization variance term does NOT start to dominate
at larger batch sizes in practice — quantized BS=256 still tracks quantized
BS=16 (relative to their fp32 counterparts).
"""
from __future__ import annotations

from repro.core.linear import Precision, make_dataset, train_linear


def run(quick: bool = False):
    rows = []
    ds = make_dataset("synthetic100", n_train=2000 if quick else 10_000,
                      n_test=2000)
    epochs = 8 if quick else 16
    results = {}
    for bs in (16, 256):
        for mode, prec in (("fp32", Precision("full")),
                           ("q6", Precision("double", bits_sample=6))):
            r = train_linear(ds, prec, epochs=epochs, batch=bs, lr=0.3)
            results[(bs, mode)] = float(r.losses[-1])
            rows.append({"batch": bs, "mode": mode,
                         "final_loss": results[(bs, mode)]})
    rows.append({
        "batch": "CHECKS", "mode": "",
        # quantized/fp32 gap does not blow up with batch size
        "quant_gap_bs16": results[(16, "q6")] / max(results[(16, "fp32")], 1e-9),
        "quant_gap_bs256": results[(256, "q6")] / max(results[(256, "fp32")], 1e-9),
        "no_batch_blowup": (results[(256, "q6")] / max(results[(256, "fp32")], 1e-9))
                            < 2.0 * max(results[(16, "q6")] / max(results[(16, "fp32")], 1e-9), 1.0),
    })
    return rows


def main():
    for row in run():
        print(",".join(f"{k}={v}" for k, v in row.items()))


if __name__ == "__main__":
    main()
