"""Serving-engine benchmark: a mixed-length request trace through the
continuous-batching engine at bf16 / int8 / packed-int4 KV.

What it measures (the ZipML serving claim: decode is KV-bandwidth-bound, so
low-precision storage is near-linear speedup):

* **KV HBM bytes** — straight from ``QTensor.nbytes`` on the paged pool
  (codes + per-row scales, §2.2 accounting). The acceptance claim: packed
  int4 moves ≥ 3× fewer KV bytes than bf16 at the bench head dim.
* **steady-state decode tokens/s** — the engine clock excludes the jit
  compile step (the timing bug the old serve loop had). On CPU the Pallas
  paged kernel runs in interpret mode, so wall-clock is a correctness-lane
  number; the bytes model is the hardware claim.
* scheduler counters — admissions, decode steps, preemptions.
* **int4 step-time parity** — the *min* steady decode-step wall-clock at
  packed int4 must not exceed int8's by more than 15%. Min, not mean/median:
  scheduler noise only ever adds time, so the min is the stable estimator
  (the same one run.py's wall-clock gate keys off via ``step_ms_min``). The
  old unpack-then-attend int4 path paid a per-page stride interleave that
  made int4 *slower* than int8 despite moving half the bytes; the
  split-nibble fusion in kernels/paged_attn.py removed it, and this CHECK
  keeps it removed.

The trace (``--smoke``/quick: 16 requests) mixes prompt lengths 4–32 and
generation lengths 4–16 over 4 decode slots — enough churn that admission,
page growth, and page recycling all fire.

The ``prefix_*`` rows replay a shared-system-prompt trace (4 prompt
families, 256 requests full / 64 quick) through a cold chunked engine
(empty cache) and a warm ``prefix_cache=True, chunk_pages=2`` engine at
each KV precision, and CHECK: prefill tokens cut ≥ 2×, outputs
token-identical to cold-start, refcounted pages drain leak-free, and
chunked prefill bounds the per-step prefill burst to one chunk — below a
monolithic engine's whole-prompt admission burst. The ``replicas_2`` row
runs the same trace through a 2-replica
:class:`~repro.launch.serve.ReplicaSet` and CHECKs balanced dispatch; the
``dispatch_prefix_vs_rr`` row replays it under prefix-aware vs round-robin
dispatch and CHECKs the prefix policy's fleet-wide warm-hit token rate
beats the affinity-blind baseline.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro import configs
from repro.launch.serve import make_trace
from repro.models import transformer as T
from repro.quant import PrecisionPlan
from repro.serve import ServeEngine

# head_dim 64 (production-ish): per KV row-head bf16 = 128 B vs int4 =
# 32 B codes + 4 B scale → 3.55× — the reduced configs' head_dim 16 would
# amortize the scale too poorly to show the claim
ARCH = "qwen2.5-14b"
HEAD_DIM = 64


def make_shared_trace(n_requests: int, vocab_size: int, *, page_size: int = 8,
                      sys_pages: int = 4, n_families: int = 4,
                      max_new: int = 8, seed: int = 1):
    """A serving trace with shared system prompts: every request opens with
    one of ``n_families`` fixed ``sys_pages``-page system prompts followed by
    a short unique suffix — the workload shape prefix caching exists for."""
    from repro.serve import Request

    rng = np.random.default_rng(seed)
    families = [rng.integers(0, vocab_size, sys_pages * page_size)
                for _ in range(n_families)]
    reqs = []
    for rid in range(n_requests):
        sys_prompt = families[int(rng.integers(0, n_families))]
        suffix = rng.integers(
            0, vocab_size, int(rng.integers(2, 2 * page_size)))
        g = int(rng.integers(max(1, max_new // 2), max_new + 1))
        reqs.append(Request(rid=rid,
                            prompt=np.concatenate([sys_prompt, suffix]),
                            max_new_tokens=g, seed=seed))
    return reqs


def run(quick: bool = False):
    n_requests = 16 if quick else 32
    max_new = 12 if quick else 24
    cfg = configs.get_reduced(ARCH)
    cfg = dataclasses.replace(cfg, head_dim=HEAD_DIM)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    trace_kw = dict(max_new=max_new, min_prompt=4, max_prompt=32, seed=0)

    rows = []
    bytes_by_bits = {}
    step_min_ms = {}
    for kv_bits in (0, 8, 4):
        engine = ServeEngine(
            params, cfg, plan=PrecisionPlan(kv_bits=kv_bits),
            max_slots=4, page_size=8, max_seq_len=32 + max_new + 8)
        trace = make_trace(n_requests, cfg.vocab_size, **trace_kw)
        results = engine.run(trace)
        assert len(results) == n_requests
        engine.allocator.check_leaks(0)
        nbytes = engine.kv_pool_nbytes()
        bytes_by_bits[kv_bits] = nbytes
        generated = sum(f.n_generated for f in results.values())
        tok_s = engine.throughput()
        # min over per-step steady wall-clock — noise only adds time, so the
        # min is the stable estimator for the parity CHECK below (median
        # flaps on a loaded CI machine at these ~2 ms step times)
        if engine.decode_times:
            step_min_ms[kv_bits] = float(np.min(engine.decode_times)) * 1e3
        row = {
            "kv": "bf16" if kv_bits == 0 else f"int{kv_bits}",
            "case": f"kv_{'bf16' if kv_bits == 0 else f'int{kv_bits}'}",
            "requests": n_requests,
            "generated": generated,
            "decode_steps": engine.stats["decode_steps"],
            "preemptions": engine.stats["preemptions"],
            "kv_pool_bytes": nbytes,
            "steady_tok_per_s": round(tok_s, 1),
        }
        # roofline annotation: KV bytes streamed per decode step (a full
        # pool sweep is the upper bound) over the measured machine peak —
        # the decode-is-KV-bandwidth-bound claim as an achieved-GB/s number
        if tok_s > 0 and engine.stats["decode_steps"]:
            from repro import perf
            step_ms = generated / tok_s / engine.stats["decode_steps"] * 1e3
            perf.annotate_row(row, bytes_moved=nbytes, ms=step_ms)
        rows.append(row)

    ratio8 = bytes_by_bits[0] / bytes_by_bits[8]
    ratio4 = bytes_by_bits[0] / bytes_by_bits[4]
    # generous 1.15× so CI jitter can't flap the gate: the regression this
    # pins was ~1.8× slower, an order of magnitude past the tolerance
    t_ratio = step_min_ms[4] / step_min_ms[8]
    rows.append({
        "kv_bytes_ratio_bf16_over_int8": round(ratio8, 2),
        "kv_bytes_ratio_bf16_over_int4": round(ratio4, 2),
        "int8_halves_kv_bytes": bool(ratio8 >= 1.8),
        "int4_ge_3x_fewer_kv_bytes": bool(ratio4 >= 3.0),
        "int4_step_ms_min": round(step_min_ms[4], 3),
        "int8_step_ms_min": round(step_min_ms[8], 3),
        "int4_decode_not_slower_than_int8": bool(t_ratio <= 1.15),
    })

    # -- prefix sharing + chunked prefill ----------------------------------
    # Same shared-system-prompt trace through a cold chunked engine (empty
    # cache) and a warm prefix-cache engine, per KV precision. CHECKs: the
    # cache cuts prefill tokens >= 2x, outputs stay token-identical to
    # cold-start (greedy), and refcounted pages drain leak-free. The cold
    # baseline is *chunked*, not monolithic: chunked prefill quantizes each
    # chunk's K/V before attending (decode-consistent, what makes prefix
    # hits exact) while monolithic prefill attends full-precision within the
    # prompt, so the two legitimately diverge at int8/int4 KV. A single
    # monolithic run supplies the stall baseline: chunking must bound the
    # per-step prefill burst to one chunk, far below whole-prompt admission.
    n_shared = 64 if quick else 256
    page, cp, sys_pages = 8, 2, 4
    chunk_tokens = cp * page

    def mk_shared(kv_bits, **kw):
        return ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=kv_bits),
                           max_slots=4, page_size=page, max_seq_len=64, **kw)

    def shared_trace():
        return make_shared_trace(n_shared, cfg.vocab_size, page_size=page,
                                 sys_pages=sys_pages)

    mono = mk_shared(8)
    mono.run(shared_trace())
    mono.allocator.check_leaks(0)
    stall_mono = mono.stats["max_prefill_tokens_per_step"]

    for kv_bits in (0, 8, 4):
        kv_name = "bf16" if kv_bits == 0 else f"int{kv_bits}"
        cold = mk_shared(kv_bits, chunk_pages=cp)
        cold_results = cold.run(shared_trace())
        cold.allocator.check_leaks(0)

        warm = mk_shared(kv_bits, prefix_cache=True, chunk_pages=cp)
        warm_results = warm.run(shared_trace())
        assert len(warm_results) == n_shared
        warm.release_prefix_cache()
        warm.allocator.check_leaks(0)

        identical = all(
            np.array_equal(cold_results[rid].tokens, warm_results[rid].tokens)
            for rid in cold_results)
        pf_cold = cold.stats["prefill_tokens"]
        pf_warm = warm.stats["prefill_tokens"]
        stall_warm = warm.stats["max_prefill_tokens_per_step"]
        rows.append({
            "case": f"prefix_{kv_name}",
            "requests": n_shared,
            "prefix_hits": warm.stats["prefix_hits"],
            "prefix_hit_tokens": warm.stats["prefix_hit_tokens"],
            "prefill_tokens_cold": pf_cold,
            "prefill_tokens_warm": pf_warm,
            "max_prefill_per_step_mono": stall_mono,
            "max_prefill_per_step_warm": stall_warm,
            "prefix_prefill_reduction_ge_2x": bool(pf_cold >= 2 * pf_warm),
            "prefix_hit_token_identical": bool(identical),
            "prefix_pages_leak_free": True,      # check_leaks(0) above raised
            "chunked_bounds_prefill_stall": bool(
                stall_warm <= chunk_tokens < stall_mono),
        })

    # -- multi-replica scaling: 2 engines behind one shared queue -----------
    from repro.launch.serve import ReplicaSet

    n_rep = 32 if quick else 64
    rs = ReplicaSet(
        lambda i: ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                              max_slots=4, page_size=page, max_seq_len=64,
                              prefix_cache=True, chunk_pages=cp),
        2)
    rep_results = rs.run(make_shared_trace(n_rep, cfg.vocab_size,
                                           page_size=page,
                                           sys_pages=sys_pages))
    for eng in rs.engines:
        eng.release_prefix_cache()
        eng.allocator.check_leaks(0)
    rows.append({
        "case": "replicas_2",
        "requests": n_rep,
        "dispatch": list(rs.dispatched),
        "prefix_hits": rs.stats_sum("prefix_hits"),
        "replicas_all_finished": bool(len(rep_results) == n_rep),
        "replicas_dispatch_balanced": bool(
            min(rs.dispatched) >= n_rep // 4),
    })

    # -- dispatch policy: prefix-aware routing vs the round-robin baseline --
    # Same replica setup, but a trace whose prefix working set only fits
    # when partitioned: 8 families x 4 system pages = 32 trie pages against
    # a 33-page pool per replica (4 slots x 8 pages + 1). One replica caching
    # its 4-family share fits alongside the active slots' private pages;
    # caching the union thrashes the trie's LRU eviction. Prefix-aware
    # dispatch creates exactly that partition (a family's requests follow
    # its trie pages), round-robin sprays every family at every replica, so
    # the prefix policy's fleet-wide warm-hit token rate must come out
    # ahead. The trace is long (96 requests) so steady-state routing, not
    # the cold-start burst dispatched before any trie exists, dominates.
    n_disp, fam_disp = 96, 8

    def disp_trace():
        return make_shared_trace(n_disp, cfg.vocab_size, page_size=page,
                                 sys_pages=sys_pages, n_families=fam_disp,
                                 max_new=4)

    def run_dispatch(policy: str):
        rset = ReplicaSet(
            lambda i: ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                                  max_slots=4, page_size=page, max_seq_len=64,
                                  prefix_cache=True, chunk_pages=cp),
            2, dispatch=policy)
        res = rset.run(disp_trace())
        assert len(res) == n_disp
        for eng in rset.engines:
            eng.release_prefix_cache()
            eng.allocator.check_leaks(0)
        return rset

    prompt_tokens = sum(len(r.prompt) for r in disp_trace())
    hit_rate = {}
    disp_row = {"case": "dispatch_prefix_vs_rr", "requests": n_disp,
                "families": fam_disp}
    for policy, key in (("prefix", "prefix"), ("round_robin", "rr")):
        rset = run_dispatch(policy)
        hit_rate[policy] = rset.stats_sum("prefix_hit_tokens") / prompt_tokens
        disp_row[f"warm_hit_rate_{key}"] = round(hit_rate[policy], 3)
        disp_row[f"dispatch_{key}"] = list(rset.dispatched)
    disp_row["prefix_dispatch_beats_round_robin"] = bool(
        hit_rate["prefix"] > hit_rate["round_robin"])
    rows.append(disp_row)

    # -- weight path at int storage: every model matmul streams codes -------
    from repro.precision.qat import quantize_param_tree
    from repro.quant import QTensor

    def w_bytes(tree, bf16: bool) -> int:
        total = 0
        for leaf in jax.tree.leaves(
                tree, is_leaf=lambda x: isinstance(x, QTensor)):
            if isinstance(leaf, QTensor):
                total += (2 * leaf.size * (2 if leaf.scheme.packed else 1)
                          if bf16 else leaf.nbytes)
        return total

    q8 = quantize_param_tree(params, bits=8)
    q4 = quantize_param_tree(params, bits=4)
    r8 = w_bytes(q8, False) / w_bytes(q8, True)
    r4 = w_bytes(q4, False) / w_bytes(q4, True)
    rows.append({"case": "weight_path",
                 "int8_ratio_vs_bf16": round(r8, 3),
                 "int4_ratio_vs_bf16": round(r4, 3),
                 "weights_int8_le_055x": bool(r8 <= 0.55),
                 "weights_int4_le_030x": bool(r4 <= 0.30)})
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
