"""Compositional roofline extraction — exact per-step FLOP/byte/collective
totals for every (arch × shape) cell on the single-pod production mesh.

Why compositional: the full-program dry-run compiles with `lax.scan` over
layers (fast, and its memory_analysis is the true peak), but XLA's
cost_analysis counts loop bodies ONCE. Here each distinct piece (layer
fwd+bwd, embed, loss head, optimizer, decode layer, …) is lowered and compiled
*separately* with the production shardings and UNROLLED inner loops, measured
with XLA's own cost model, then composed:

    train   = accum × (embed' + L × layer' + loss') + optimizer
    prefill = embed + L × layer_collect + readout_last
    decode  = embed₁ + L × layer_decode + readout₁

(' = includes the backward). Per-piece compiles are seconds each, so the
whole 33-cell table lands in ~10 min on one CPU core.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_roofline --arch gemma-7b --shape train_4k
  PYTHONPATH=src python -m benchmarks.bench_roofline --all --out roofline.json
"""
from __future__ import annotations

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import hlostats as H
from repro.launch import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models import transformer as T
from repro.optim import adamw

_PIECE_CACHE: dict = {}


def _compile_piece(name, fn, arg_specs, arg_shardings, mesh, donate=(),
                   out_shardings=None):
    key = name
    if key in _PIECE_CACHE:
        return _PIECE_CACHE[key]
    kw = {"out_shardings": out_shardings} if out_shardings is not None else {}
    jfn = jax.jit(fn, in_shardings=arg_shardings, donate_argnums=donate, **kw)
    with jax.sharding.set_mesh(mesh):
        compiled = jfn.lower(*arg_specs).compile()
    stats = H.compiled_stats(compiled)
    stats["name"] = name
    _PIECE_CACHE[key] = stats
    return stats


def _tree_shardings(mesh, tree, spec_fn):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec_fn(path, leaf)), tree)


def _named(mesh, spec):
    return NamedSharding(mesh, spec)


def _layer_specs(cfg):
    """ShapeDtypeStructs for ONE (unstacked) layer of each kind."""
    return jax.eval_shape(lambda: T._init_layer(cfg, jax.random.PRNGKey(0)))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def measure_cell(arch: str, shape_name: str, precision=None,
                 accum_override=None, verbose=True) -> dict:
    from repro.launch.dryrun import TRAIN_ACCUM, model_flops_for  # shares tables
    mesh = make_production_mesh(multi_pod=False)
    shape = configs.SHAPES[shape_name]
    overrides = {"dp_axes": ("data",), "scan_layers": False, "q_chunk": 2048,
                 "ssd_chunk": 2048 if shape.kind == "prefill" else 1024}
    if precision is not None:
        overrides["precision"] = precision
    cfg = configs.get_config(arch, **overrides)
    accum = accum_override or (TRAIN_ACCUM.get(cfg.name, 1)
                               if shape.kind == "train" else 1)
    b = shape.global_batch // accum if shape.kind == "train" else shape.global_batch
    s = shape.seq_len
    dtype = cfg.dtype
    act = _sds((b, s, cfg.d_model), dtype)
    act_sh = _named(mesh, T._act_spec(cfg))
    lp = _layer_specs(cfg)
    if cfg.precision.model_bits and cfg.precision.model_storage == "int" \
            and shape.kind != "train":
        from repro.precision.qat import quantize_param_tree
        lp = jax.eval_shape(
            lambda q: quantize_param_tree(q, cfg.precision.model_bits), lp)
    lp_sh = _tree_shardings(mesh, lp, sh.param_spec)
    emb = jax.eval_shape(lambda: {"t": T.init_embedding(
        jax.random.PRNGKey(0), cfg.vocab_padded, cfg.d_model, dtype)["table"]})
    emb_spec = {"table": emb["t"]}
    emb_sh = {"table": _named(mesh, P("model", None))}
    tag = f"{arch}/{shape_name}/{cfg.precision}"

    pieces = []   # (stats, multiplier)

    if shape.kind in ("train",):
        tok = _sds((b, s), jnp.int32)
        tok_sh = _named(mesh, P("data", None))

        # --- embed (fwd+bwd: scatter-add of cot into the table) ---
        def embed_fb(table, tokens, cot):
            x = jnp.take(table["table"], tokens, axis=0).astype(dtype)
            # bwd wrt table via vjp, weighted by cot
            return jnp.sum(x.astype(jnp.float32) * cot)
        g_embed = jax.grad(embed_fb, argnums=0)
        st = _compile_piece(
            tag + "/embed", g_embed,
            (emb_spec, tok, _sds((b, s, cfg.d_model), jnp.float32)),
            (emb_sh, tok_sh, act_sh), mesh, out_shardings=emb_sh)
        pieces.append((st, accum))

        # --- one layer fwd+bwd ---
        from repro.precision import qat as qat_mod

        def layer_fb(layer, x):
            if cfg.precision.model_bits and cfg.precision.model_storage == "ship":
                layer = qat_mod.ship_quant_tree(layer, cfg.precision.model_bits)
            y = T._layer_fwd(cfg, layer, x)
            return jnp.sum(y.astype(jnp.float32))
        g_layer = jax.value_and_grad(layer_fb, argnums=(0, 1))
        repl = _named(mesh, P())
        st = _compile_piece(tag + "/layer", g_layer, (lp, act),
                            (lp_sh, act_sh), mesh,
                            out_shardings=(repl, (lp_sh, act_sh)))
        n_main = cfg.n_layers
        pieces.append((st, accum * n_main))

        # hybrid / vlm extra blocks
        if cfg.family == "hybrid":
            blk = jax.eval_shape(lambda: T._init_attn_block(cfg, jax.random.PRNGKey(0)))
            blk_sh = _tree_shardings(mesh, blk, sh.param_spec)
            def blk_fb(bp, x):
                return jnp.sum(T._attn_block_fwd(cfg, bp, x).astype(jnp.float32))
            st = _compile_piece(tag + "/shared", jax.value_and_grad(blk_fb, argnums=(0, 1)),
                                (blk, act), (blk_sh, act_sh), mesh,
                                out_shardings=(_named(mesh, P()), (blk_sh, act_sh)))
            pieces.append((st, accum * (cfg.n_layers // cfg.shared_attn_every)))
        if cfg.family == "vlm":
            blk = jax.eval_shape(lambda: T._init_attn_block(cfg, jax.random.PRNGKey(0), cross=True))
            blk_sh = _tree_shardings(mesh, blk, sh.param_spec)
            vis = _sds((b, cfg.n_vis_tokens, cfg.d_model), jnp.float32)
            vis_sh = _named(mesh, P("data", None, None))
            def cross_fb(bp, x, v):
                return jnp.sum(T._attn_block_fwd(cfg, bp, x, kv_tokens=v.astype(dtype))
                               .astype(jnp.float32))
            st = _compile_piece(tag + "/cross", jax.value_and_grad(cross_fb, argnums=(0, 1)),
                                (blk, act, vis), (blk_sh, act_sh, vis_sh), mesh,
                                out_shardings=(_named(mesh, P()), (blk_sh, act_sh)))
            pieces.append((st, accum * (cfg.n_layers // cfg.cross_attn_every)))

        # --- loss head fwd+bwd (tied readout) ---
        def loss_fb(table, h, targets):
            params = {"embed": {"table": table["table"]},
                      "final_norm": {"g": jnp.zeros((cfg.d_model,), dtype)}}
            # chunked xent exactly as transformer.loss_fn (unrolled)
            hh = T.rmsnorm(params["final_norm"], h)
            cs = min(cfg.logit_chunk, s)
            n_chunks = s // cs
            dpa = "data"
            total = jnp.float32(0.0)
            for i in range(n_chunks):
                hc = jax.lax.dynamic_slice_in_dim(hh, i * cs, cs, axis=1)
                tc = jax.lax.dynamic_slice_in_dim(targets, i * cs, cs, axis=1)
                logits = T._readout(params, cfg, hc)
                logits = T.shard_hint(logits, P(dpa, None, "model"))
                logz = jax.nn.logsumexp(logits, axis=-1)
                vpos = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
                gold = jnp.sum(jnp.where(vpos == tc[..., None], logits, 0.0), -1)
                total = total + jnp.sum(logz - gold)
            return total / (b * s)
        g_loss = jax.value_and_grad(loss_fb, argnums=(0, 1))
        st = _compile_piece(tag + "/loss", g_loss, (emb_spec, act, tok),
                            (emb_sh, act_sh, tok_sh), mesh,
                            out_shardings=(_named(mesh, P()), (emb_sh, act_sh)))
        pieces.append((st, accum))

        # --- optimizer ---
        params = T.param_specs(cfg)
        p_sh = sh.make_param_shardings(mesh, params)
        ocfg = adamw.AdamWConfig()
        opt = jax.eval_shape(lambda p: adamw.init(p, ocfg), params)
        o_sh = sh.make_opt_shardings(mesh, opt)
        def opt_piece(p, g, o):
            return adamw.apply_updates(p, g, o, ocfg)
        st = _compile_piece(tag + "/opt", opt_piece, (params, params, opt),
                            (p_sh, p_sh, o_sh), mesh, donate=(0, 2))
        pieces.append((st, 1))

    elif shape.kind == "prefill":
        def layer_f(layer, x):
            if cfg.family in ("ssm", "hybrid"):
                out, mc = ssm_mod.mamba2_forward(
                    layer["mamba"], T.rmsnorm(layer["norm"], x), cfg.ssm_spec,
                    return_state=True)
                return x + out, mc
            a_out, (kk, vv) = attn.attention_block(
                layer["attn"], T.rmsnorm(layer["ln1"], x), cfg.attn_spec,
                return_kv=True)
            h = x + a_out
            z = T.rmsnorm(layer["ln2"], h)
            if cfg.family == "moe":
                from repro.models import moe as moe_mod
                y = moe_mod.moe_block(layer["moe"], z, cfg.moe_spec)
            else:
                y = T.mlp(layer["mlp"], z, cfg.mlp_act)
            cache = attn.prefill_cache_from_kv(kk, vv, window=cfg.window,
                                               kv_bits=cfg.precision.kv_bits)
            return h + y, cache
        st = _compile_piece(tag + "/layer_prefill", layer_f, (lp, act),
                            (lp_sh, act_sh), mesh)
        pieces.append((st, cfg.n_layers))
        if cfg.family == "hybrid":
            blk = jax.eval_shape(lambda: T._init_attn_block(cfg, jax.random.PRNGKey(0)))
            blk_sh = _tree_shardings(mesh, blk, sh.param_spec)
            def blk_f(bp, x):
                return T._attn_block_fwd(cfg, bp, x)
            st = _compile_piece(tag + "/shared_prefill", blk_f, (blk, act),
                                (blk_sh, act_sh), mesh)
            pieces.append((st, cfg.n_layers // cfg.shared_attn_every))

        def head_f(table, tokens, h):
            x = jnp.take(table["table"], tokens, axis=0).astype(dtype)
            params = {"embed": {"table": table["table"]},
                      "final_norm": {"g": jnp.zeros((cfg.d_model,), dtype)}}
            hl = T.rmsnorm(params["final_norm"], h[:, -1:, :])
            return jnp.sum(x.astype(jnp.float32)), T._readout(params, cfg, hl)
        tok = _sds((b, s), jnp.int32)
        st = _compile_piece(tag + "/head_prefill", head_f,
                            (emb_spec, tok, act),
                            (emb_sh, _named(mesh, P("data", None)), act_sh), mesh)
        pieces.append((st, 1))

    else:  # decode
        x1 = _sds((b, 1, cfg.d_model), dtype)
        bspec = sh.batch_spec(mesh, b)
        x1_sh = _named(mesh, P(bspec, None, None))
        state = jax.eval_shape(lambda: T.init_decode_state(cfg, b, smax=s))
        kvb = cfg.precision.kv_bits

        def one_layer_cache(tree):
            # drop the stacked layer dim from the SDS skeleton
            return jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), tree)
        lc = one_layer_cache(state.layers)
        lc_sh = sh.cache_shardings(mesh, lc, b)  # rules are ndim-aware

        if cfg.family in ("ssm", "hybrid"):
            def dec_layer(layer, cache, x):
                z = T.rmsnorm(layer["norm"], x)
                y, nc = ssm_mod.mamba2_decode_step(layer["mamba"], z, cache,
                                                   cfg.ssm_spec)
                return x + y, nc
            st = _compile_piece(tag + "/layer_decode", dec_layer, (lp, lc, x1),
                                (lp_sh, lc_sh, x1_sh), mesh, donate=(1,))
            pieces.append((st, cfg.n_layers))
            if cfg.family == "hybrid":
                blk = jax.eval_shape(lambda: T._init_attn_block(cfg, jax.random.PRNGKey(0)))
                blk_sh = _tree_shardings(mesh, blk, sh.param_spec)
                sc = one_layer_cache(state.shared)
                sc_sh = sh.cache_shardings(mesh, sc, b)
                def dec_shared(bp, cache, x):
                    z = T.rmsnorm(bp["ln1"], x)
                    a_out, nc = attn.attention_decode_step(bp["attn"], z, cache,
                                                           cfg.attn_spec, kv_bits=kvb)
                    h = x + a_out
                    h = h + T.mlp(bp["mlp"], T.rmsnorm(bp["ln2"], h), cfg.mlp_act)
                    return h, nc
                st = _compile_piece(tag + "/shared_decode", dec_shared,
                                    (blk, sc, x1), (blk_sh, sc_sh, x1_sh), mesh,
                                    donate=(1,))
                pieces.append((st, cfg.n_layers // cfg.shared_attn_every))
        else:
            def dec_layer(layer, cache, x):
                z = T.rmsnorm(layer["ln1"], x)
                a_out, nc = attn.attention_decode_step(layer["attn"], z, cache,
                                                       cfg.attn_spec, kv_bits=kvb)
                h = x + a_out
                if cfg.family == "moe":
                    from repro.models import moe as moe_mod
                    y = moe_mod.moe_block(layer["moe"], T.rmsnorm(layer["ln2"], h),
                                          cfg.moe_spec)
                else:
                    y = T.mlp(layer["mlp"], T.rmsnorm(layer["ln2"], h), cfg.mlp_act)
                return h + y, nc
            st = _compile_piece(tag + "/layer_decode", dec_layer, (lp, lc, x1),
                                (lp_sh, lc_sh, x1_sh), mesh, donate=(1,))
            pieces.append((st, cfg.n_layers))
            if cfg.family == "vlm":
                blk = jax.eval_shape(
                    lambda: T._init_attn_block(cfg, jax.random.PRNGKey(0), cross=True))
                blk_sh = _tree_shardings(mesh, blk, sh.param_spec)
                ck = _sds((b, cfg.n_vis_tokens, cfg.n_kv_heads, cfg.head_dim), dtype)
                ck_sh = _named(mesh, P(bspec, None, None, None))
                def dec_cross(bp, x, ckk, cvv):
                    return T._cross_decode(cfg, bp, x, ckk, cvv)
                st = _compile_piece(tag + "/cross_decode", dec_cross,
                                    (blk, x1, ck, ck), (blk_sh, x1_sh, ck_sh, ck_sh),
                                    mesh)
                pieces.append((st, cfg.n_layers // cfg.cross_attn_every))

        def head_dec(table, tokens, h):
            x = jnp.take(table["table"], tokens, axis=0).astype(dtype)
            params = {"embed": {"table": table["table"]},
                      "final_norm": {"g": jnp.zeros((cfg.d_model,), dtype)}}
            hl = T.rmsnorm(params["final_norm"], h)
            return jnp.sum(x.astype(jnp.float32)), T._readout(params, cfg, hl)
        tok1 = _sds((b, 1), jnp.int32)
        st = _compile_piece(tag + "/head_decode", head_dec, (emb_spec, tok1, x1),
                            (emb_sh, _named(mesh, P(bspec, None)), x1_sh), mesh)
        pieces.append((st, 1))

    if verbose:
        for st, w in pieces:
            print(f"    piece {st.get('name','?').split('/')[-1]:16s} ×{w:4d}: "
                  f"flops {st['flops']:.2e} hbm {st['hbm_bytes']:.2e} "
                  f"coll {st['collective_bytes']:.2e} "
                  f"{ {k: f'{v:.1e}' for k, v in st['collective_breakdown'].items() if v} }")
    total = H.add_stats(*[p[0] for p in pieces],
                        weights=[p[1] for p in pieces])
    terms = H.roofline_terms(total)
    mf = model_flops_for(cfg, shape)
    n_dev = 256
    result = {
        "arch": arch, "shape": shape_name, "mesh": "16x16", "accum": accum,
        **{k: total[k] for k in ("flops", "hbm_bytes", "collective_bytes")},
        "collective_breakdown": total["collective_breakdown"],
        **terms,
        "model_flops": mf,
        "useful_ratio": mf / (total["flops"] * n_dev) if total["flops"] else 0.0,
        "dominant": max(terms, key=terms.get).replace("_term_s", ""),
    }
    if verbose:
        print(f"[{arch} × {shape_name}] compute {terms['compute_term_s']*1e3:.2f} ms | "
              f"memory {terms['memory_term_s']*1e3:.2f} ms | "
              f"collective {terms['collective_term_s']*1e3:.2f} ms "
              f"→ {result['dominant']}-bound, useful={result['useful_ratio']:.3f}")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--kv-bits", type=int, default=0)
    ap.add_argument("--weight-bits", type=int, default=0)
    ap.add_argument("--weight-storage", default="int", choices=("int", "ship", "fake"))
    args = ap.parse_args(argv)
    precision = None
    if args.kv_bits or args.weight_bits:
        from repro.quant import PrecisionPlan
        precision = PrecisionPlan(model_bits=args.weight_bits,
                                  model_storage=args.weight_storage,
                                  kv_bits=args.kv_bits)
    cells = configs.all_cells() if args.all else [(args.arch, args.shape)]
    results = []
    for arch, shape in cells:
        t0 = time.time()
        try:
            r = measure_cell(arch, shape, precision=precision)
        except Exception as e:  # noqa: BLE001
            r = {"arch": arch, "shape": shape, "error": f"{type(e).__name__}: {e}"}
            print(f"[{arch} × {shape}] FAILED: {r['error'][:300]}")
        r["wall_s"] = time.time() - t0
        results.append(r)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
    return 0


if __name__ == "__main__":
    sys.exit(main())
