"""Fused vs two-pass double sampling — the §2.2 data-movement claim, measured.

Two accounting views plus a wall-clock probe:

* **HBM traffic per quantization** — the two-pass path streams the f32 batch
  (and a rand plane) once per draw and writes a full code plane each time; the
  fused kernel reads x/rand once and emits both planes. Deterministic model,
  counted in bytes actually touched.
* **Wire/storage bits per coordinate** — independent planes cost 2·log₂(s+1)
  bits; the shared-base layout costs log₂(s+1) + 1 (the paper's "log₂(k) extra
  bits for k samples", k=2).
* **Wall-clock** — fused ``ops.ds_quantize`` vs two ``ops.quantize_rows``
  calls, and the int8-codes gradient vs the dequantized-f32 two-pass gradient.
  (On CPU the Pallas kernels run in interpret mode, so absolute times are
  correctness-lane numbers; the bytes model is the hardware claim.)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import quant
from repro.core.double_sampling import lsq_gradient_double_sampling
from repro.kernels import ops
from repro.quant import QScheme


def hbm_bytes(r: int, c: int, fused: bool) -> int:
    """Bytes moved to quantize an (r, c) f32 batch into two int8 code planes."""
    read_x, read_rand, write_codes = 4 * r * c, 4 * r * c, r * c
    if fused:
        return read_x + read_rand + 2 * write_codes
    return 2 * (read_x + read_rand + write_codes)


def wire_bits(s: int, fused: bool) -> float:
    per_plane = float(np.log2(s + 1))
    return per_plane + 1 if fused else 2 * per_plane


def _time(fn, reps: int) -> float:
    fn()  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e3  # ms


def run(quick: bool = False):
    rows = []
    r, c = (256, 512) if quick else (1024, 2048)
    s = 7
    reps = 3 if quick else 10
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (r, c), jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=0)  # column scaling, pipeline convention

    fused_b, twopass_b = hbm_bytes(r, c, True), hbm_bytes(r, c, False)
    rows.append({
        "path": "hbm_bytes_model", "shape": f"{r}x{c}", "s": s,
        "fused_bytes": fused_b, "two_pass_bytes": twopass_b,
        "reduction": round(twopass_b / fused_b, 3),
    })
    rows.append({
        "path": "wire_bits_per_coord", "s": s,
        "fused_bits": wire_bits(s, True), "two_pass_bits": wire_bits(s, False),
        "reduction": round(wire_bits(s, False) / wire_bits(s, True), 3),
    })

    # the same accounting, read straight off the storage format: one QTensor
    # holding both DS planes reports bits+1 per coordinate via .nbits/.nbytes
    qt = quant.ds_pair(x, QScheme.zipml(s, scaling="column", rounding="ds"),
                       key, scale=scale, backend="ref")  # accounting only
    rows.append({
        "path": "qtensor_nbytes", "shape": f"{r}x{c}", "s": s,
        "nbits_per_coord": qt.nbits, "hbm_bytes": qt.nbytes,
        "fp32_bytes": 4 * r * c,
        "reduction_vs_fp32": round(4 * r * c / qt.nbytes, 3),
    })

    def fused_quant():
        c1, c2, _ = ops.ds_quantize(x, s, key, scale=scale)
        c1.block_until_ready(), c2.block_until_ready()

    def two_pass_quant():
        k1, k2 = jax.random.split(key)
        ops.quantize_rows(x, s, k1)[0].block_until_ready()
        ops.quantize_rows(x, s, k2)[0].block_until_ready()

    t_fused = _time(fused_quant, reps)
    t_two = _time(two_pass_quant, reps)
    rows.append({"path": "quant_wallclock", "shape": f"{r}x{c}",
                 "fused_ms": round(t_fused, 2), "two_pass_ms": round(t_two, 2),
                 "speedup": round(t_two / t_fused, 3)})

    # gradient: int8-codes matvecs vs dequantized-f32 two-pass math
    n = c
    xw = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 2), (r,), jnp.float32)
    c1, c2, sc = ops.ds_quantize(x, s, key, scale=scale)

    def grad_codes():
        ops.ds_gradient_from_codes(c1, c2, xw, b, sc, s).block_until_ready()

    @jax.jit
    def _grad_deq(c1, c2, sc):
        q1 = c1.astype(jnp.float32) / s * sc
        q2 = c2.astype(jnp.float32) / s * sc
        return (q1.T @ (q2 @ xw - b) + q2.T @ (q1 @ xw - b)) / (2.0 * r)

    def grad_deq():
        _grad_deq(c1, c2, sc).block_until_ready()

    t_gc = _time(grad_codes, reps)
    t_gd = _time(grad_deq, reps)
    # correctness cross-check rides along: same codes → same gradient
    err = float(jnp.linalg.norm(
        ops.ds_gradient_from_codes(c1, c2, xw, b, sc, s) - _grad_deq(c1, c2, sc))
        / (jnp.linalg.norm(_grad_deq(c1, c2, sc)) + 1e-9))
    rows.append({"path": "grad_wallclock", "shape": f"{r}x{c}",
                 "codes_ms": round(t_gc, 2), "dequant_f32_ms": round(t_gd, 2),
                 "rel_err_vs_dequant": f"{err:.2e}"})

    # end-to-end registry dispatch sanity (one step each backend)
    g_ref = lsq_gradient_double_sampling(xw, x, b, s, key, scale=scale,
                                         backend="ref")
    g_pl = lsq_gradient_double_sampling(xw, x, b, s, key, scale=scale,
                                        backend="pallas")
    rows.append({"path": "CHECKS",
                 "fused_moves_fewer_bytes": fused_b < twopass_b,
                 "wire_overhead_is_one_bit":
                     abs(wire_bits(s, True) - (np.log2(s + 1) + 1)) < 1e-9,
                 "qtensor_nbits_matches_wire_model":
                     abs(qt.nbits - wire_bits(s, True)) < 1e-9,
                 "grad_paths_agree": err < 1e-3,
                 "backends_finite": bool(np.isfinite(np.asarray(g_ref)).all()
                                         and np.isfinite(np.asarray(g_pl)).all())})
    return rows


def main():
    for row in run(quick=True):
        print(",".join(f"{k}={v}" for k, v in row.items()))


if __name__ == "__main__":
    main()
