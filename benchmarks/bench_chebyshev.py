"""Fig. 9 — Chebyshev-approximated gradients for SVM + logistic regression,
AND the paper's §5.4 negative result: an 8-bit nearest-rounding straw man
matches the Chebyshev machinery.
"""
from __future__ import annotations

from repro.core.linear import Precision, eval_accuracy, make_dataset, train_linear


def run(quick: bool = False):
    rows = []
    epochs = 6 if quick else 12
    ds = make_dataset("cod-rna", n_train=3000 if quick else 10_000, n_test=5000)
    for model in ("logistic", "svm"):
        results = {}
        runs = {
            "fp32": dict(prec=Precision("full")),
            # degree-15 poly × 4-bit samples ≈ 8 bits total (§5.4 accounting)
            "cheb_8bit": dict(prec=Precision("double", bits_sample=4)),
            "nearest_8bit": dict(prec=Precision("nearest", bits_sample=8)),
        }
        for name, kw in runs.items():
            r = train_linear(ds, kw["prec"], model=model, epochs=epochs,
                             lr=0.4 if model == "logistic" else 0.2,
                             reg="ball" if model == "svm" else "none")
            results[name] = (float(r.losses[-1]), eval_accuracy(ds, r.x))
            rows.append({"model": model, "mode": name,
                         "final_loss": results[name][0],
                         "test_acc": results[name][1]})
        # SVM's Chebyshev path carries the §4.2 ‖x‖≤R/‖a‖ constraint (the step
        # polynomial is only valid on [-R,R]) — the paper's own point is that
        # the unconstrained straw man does at least as well (negative result)
        tol = 0.05 if model == "logistic" else 0.12
        rows.append({
            "model": model, "mode": "CHECKS",
            "cheb_close_to_fp32_acc": results["cheb_8bit"][1]
                                       > results["fp32"][1] - tol,
            # the NEGATIVE result: the straw man is at least as good
            "strawman_matches_cheb": results["nearest_8bit"][1]
                                      >= results["cheb_8bit"][1] - 0.02,
        })
    return rows


def main():
    for row in run():
        print(",".join(f"{k}={v}" for k, v in row.items()))


if __name__ == "__main__":
    main()
