"""Chaos benchmark: deterministic fault injection through the serving stack.

Every row drives the engine / replica set on a **virtual clock**
(:class:`repro.serve.VirtualClock`) with a seeded
:class:`repro.serve.FaultInjector`, so a chaos run costs no wall time and
replays bit-identically — the CHECKs are exact invariants, not statistics:

* ``migrate_<kv>`` (bf16 / int8 / int4 KV) — a 2-replica
  :class:`~repro.launch.serve.ReplicaSet` serves a shared-prefix trace;
  replica 0 is killed mid-trace by injected device-loss raises (two in a
  row walks its health machine healthy → suspect → dead). CHECKs: every
  request completes **exactly once**, every request's tokens are identical
  to the fault-free run (migrated requests replay prompt + committed
  tokens through the recompute-preemption machinery — bit-exact, so the
  failure is output-invisible), work actually migrated, the dead replica
  restarted from the factory, p99 admission wait stays bounded in virtual
  seconds, and every replica's pool drains leak-free.
* ``quarantine_nan`` — an injected NaN-logits fault poisons one request
  mid-decode. CHECKs: that request alone fails with ``reason='nan'``
  (engine keeps serving), every other request is token-identical to the
  clean run, the quarantined slot's pages are scrubbed + freed (leak-free
  drain), and exactly one quarantine is counted.
* ``trie_corrupt_int4`` — bits are flipped in a shared prefix-trie page
  between two request waves. CHECKs: the checksum re-verification at
  ``use`` time evicts the corrupt page (never attends it), the second wave
  re-prefills cold and stays token-identical to an engine that never had a
  cache, and the eviction is counted.

The fault specs and the ``fired`` audit log together form a replayable
chaos trace; rerunning with the same specs reproduces the run exactly.
"""
from __future__ import annotations

import jax
import numpy as np

from repro import configs
from repro.models import transformer as T
from repro.quant import PrecisionPlan
from repro.serve import FaultInjector, FaultSpec, ServeEngine, VirtualClock
from repro.serve.faults import corrupt_kv_page

from benchmarks.bench_serve_engine import make_shared_trace

ARCH = "qwen2.5-14b"
PAGE = 8
DT = 0.01                 # virtual seconds advanced per driver iteration
KILL_STEPS = (6, 7)       # set-level steps the device-loss raises fire at
P99_BOUND_S = 2.0         # virtual-clock admission bound under one death


def _mk_cfg():
    cfg = configs.get_reduced(ARCH)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _drive(rs, trace, clock, max_steps: int = 20_000):
    """Run a ReplicaSet to drain on the virtual clock, advancing ``DT`` per
    scheduler iteration. Returns (results, duplicate-finish count)."""
    for r in trace:
        rs.submit(r)
    out, dupes = {}, 0
    for _ in range(max_steps):
        if not rs._queue and not any(e.busy for e in rs.engines):
            return out, dupes
        for rid, f in rs.step().items():
            if rid in out:
                dupes += 1
            out[rid] = f
        clock.advance(DT)
    raise RuntimeError(f"chaos drive exceeded {max_steps} steps")


def _admit_p99(rs) -> float:
    waits = [w for e in rs.engines for w in e.admit_waits]
    return float(np.percentile(waits, 99)) if waits else 0.0


def _migration_case(cfg, params, kv_bits: int, n_requests: int):
    from repro.launch.serve import HealthConfig, ReplicaSet

    kv_name = "bf16" if kv_bits == 0 else f"int{kv_bits}"

    def build(faults):
        clock = VirtualClock()
        if faults is not None:
            faults.clock = clock

        def factory(i):
            return ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=kv_bits),
                               max_slots=4, page_size=PAGE, max_seq_len=64,
                               prefix_cache=True, chunk_pages=2, clock=clock,
                               fault_injector=faults, replica_id=i)

        rs = ReplicaSet(factory, 2, clock=clock, fault_injector=faults,
                        health=HealthConfig(step_deadline_s=30.0, dead_after=2,
                                            restart_backoff_s=0.2,
                                            backoff_cap_s=1.0, max_restarts=3))
        return rs, clock

    def trace():
        return make_shared_trace(n_requests, cfg.vocab_size, page_size=PAGE,
                                 sys_pages=4, max_new=8, seed=1)

    rs, clock = build(None)
    clean, dupes = _drive(rs, trace(), clock)
    assert dupes == 0 and len(clean) == n_requests

    faults = FaultInjector([
        FaultSpec("replica_raise", at_step=s, replica=0) for s in KILL_STEPS])
    rs, clock = build(faults)
    out, dupes = _drive(rs, trace(), clock)
    for eng in rs.engines:
        eng.release_prefix_cache()
        eng.allocator.check_leaks(0)
    identical = all(np.array_equal(clean[rid].tokens, out[rid].tokens)
                    for rid in clean)
    p99 = _admit_p99(rs)
    return {
        "case": f"migrate_{kv_name}",
        "requests": n_requests,
        "deaths": rs.stats["deaths"],
        "migrated": rs.stats["migrated"],
        "restarts": rs.stats["restarts"],
        "faults_fired": len(faults.fired),
        "p99_admit_virtual_s": round(p99, 4),
        "all_requests_completed": bool(len(out) == n_requests),
        "exactly_once": bool(dupes == 0),
        "migration_token_identical": bool(identical),
        "work_migrated": bool(rs.stats["migrated"] > 0),
        "replica_restarted": bool(rs.stats["restarts"] >= 1),
        "p99_admit_bounded": bool(p99 <= P99_BOUND_S),
        "pools_leak_free": True,             # check_leaks(0) above raised
    }


def _quarantine_case(cfg, params, n_requests: int):
    def build(faults):
        clock = VirtualClock()
        if faults is not None:
            faults.clock = clock
        return ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                           max_slots=4, page_size=PAGE, max_seq_len=64,
                           chunk_pages=2, clock=clock, fault_injector=faults)

    def trace():
        return make_shared_trace(n_requests, cfg.vocab_size, page_size=PAGE,
                                 sys_pages=4, max_new=8, seed=2)

    clean = build(None).run(trace())
    poison_rid = 0
    eng = build(FaultInjector([
        FaultSpec("nan_logits", at_step=6, rid=poison_rid)]))
    out = eng.run(trace())
    eng.allocator.check_leaks(0)
    others_identical = all(
        np.array_equal(clean[rid].tokens, out[rid].tokens)
        for rid in clean if rid != poison_rid)
    return {
        "case": "quarantine_nan",
        "requests": n_requests,
        "poisoned_rid": poison_rid,
        "quarantined": eng.stats["quarantined"],
        "poisoned_failed_with_status": bool(out[poison_rid].reason == "nan"),
        "engine_survived_all_finished": bool(len(out) == n_requests),
        "others_token_identical": bool(others_identical),
        "exactly_one_quarantine": bool(eng.stats["quarantined"] == 1),
        "pool_leak_free": True,
    }


def _trie_corruption_case(cfg, params, n_requests: int):
    def mk(prefix: bool):
        return ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=4),
                           max_slots=4, page_size=PAGE, max_seq_len=64,
                           prefix_cache=prefix, chunk_pages=2,
                           clock=VirtualClock())

    def wave(seed):
        return make_shared_trace(n_requests, cfg.vocab_size, page_size=PAGE,
                                 sys_pages=4, n_families=1, max_new=8,
                                 seed=seed)

    # cold reference: chunked engine that never had a cache
    cold = mk(False)
    cold_out = cold.run(wave(4))
    cold.allocator.check_leaks(0)

    warm = mk(True)
    warm.run(wave(4))                        # wave 1 populates the trie
    victim = warm.prefix.match(
        np.asarray(wave(4)[0].prompt, np.int32))[0]
    warm.pool = corrupt_kv_page(warm.pool, victim, n_flips=4, seed=7)
    warm_out = warm.run(wave(4))             # wave 2 must not attend it
    warm.release_prefix_cache()
    warm.allocator.check_leaks(0)
    identical = all(np.array_equal(cold_out[rid].tokens, warm_out[rid].tokens)
                    for rid in cold_out)
    return {
        "case": "trie_corrupt_int4",
        "requests": n_requests,
        "victim_page": int(victim),
        "corrupt_evictions": warm.prefix.corrupt_evictions,
        "corrupt_page_evicted": bool(warm.prefix.corrupt_evictions >= 1),
        "reprefill_token_identical_to_cold": bool(identical),
        "pool_leak_free": True,
    }


def run(quick: bool = False):
    n_requests = 24 if quick else 48
    cfg, params = _mk_cfg()
    rows = []
    for kv_bits in (0, 8, 4):
        rows.append(_migration_case(cfg, params, kv_bits, n_requests))
    rows.append(_quarantine_case(cfg, params, 12 if quick else 24))
    rows.append(_trie_corruption_case(cfg, params, 8))
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
