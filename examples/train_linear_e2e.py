"""The paper's core experiment end-to-end: train linear regression and
least-squares SVM with every channel quantized (samples via double sampling,
model, gradient), sweeping the sample bit width — Fig. 4 in miniature.

Run: PYTHONPATH=src python examples/train_linear_e2e.py
"""
from repro.core.linear import eval_accuracy, eval_mse, make_dataset, train_linear
from repro.quant import PrecisionPlan

for ds_name, model in (("synthetic100", "linreg"), ("cod-rna", "lssvm")):
    ds = make_dataset(ds_name, n_train=5000, n_test=2000)
    print(f"\n=== {model} on {ds_name} ===")
    full = train_linear(ds, PrecisionPlan("full"), model=model, epochs=12, lr=0.3)
    print(f"fp32        : loss={full.losses[-1]:.5f}")
    for bits in (3, 4, 6, 8):
        prec = PrecisionPlan("e2e", sample_bits=bits, model_bits=8, grad_bits=8)
        r = train_linear(ds, prec, model=model, epochs=12, lr=0.3)
        extra = (f" acc={eval_accuracy(ds, r.x):.3f}" if model == "lssvm" else
                 f" test_mse={eval_mse(ds, r.x):.5f}")
        print(f"e2e {bits}-bit  : loss={r.losses[-1]:.5f}{extra}")
print("\n(5–6 bits matches fp32 — the paper's Fig. 4 claim.)")
