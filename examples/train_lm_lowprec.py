"""End-to-end LM training driver demo: a ~100M-param musicgen-family decoder
trained for a few hundred steps on this host with the ZipML channels on —
QAT 8-bit weights, 8-bit gradient compression with error feedback, 8-bit
optimizer moments — including a checkpoint/restore cycle.

Run: PYTHONPATH=src python examples/train_lm_lowprec.py  (~10-20 min CPU)
Pass --tiny for a 2-minute version.
"""
import argparse
import tempfile

from repro.launch.train import train

ap = argparse.ArgumentParser()
ap.add_argument("--tiny", action="store_true")
ap.add_argument("--steps", type=int, default=None)
args = ap.parse_args()

steps = args.steps or (60 if args.tiny else 300)
batch, seq = (4, 64) if args.tiny else (8, 256)

with tempfile.TemporaryDirectory() as ckpt:
    _, losses = train(
        "musicgen-medium",      # 1536-wide decoder family; reduced depth/width
        reduced=True, steps=steps, batch=batch, seq=seq,
        ckpt_dir=ckpt, ckpt_every=max(steps // 4, 10),
        grad_bits=8, weight_bits=8, moment_bits=8, lr=3e-3, log_every=20)
print(f"loss: {losses[0]:.3f} → {losses[-1]:.3f} "
      "(all three ZipML channels quantized)")
assert losses[-1] < losses[0], "training did not improve"
