"""Quickstart: the ZipML core in 60 seconds.

1. Stochastic quantization is unbiased; naive quantized gradients are not.
2. Double sampling fixes the bias — low-precision SGD converges to the fp32
   solution.
3. Variance-optimal levels (the DP) beat the uniform grid at equal bits.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import optimal
from repro.core.double_sampling import (
    lsq_gradient_double_sampling, lsq_gradient_fullprec, lsq_gradient_naive_quant)
from repro.core.linear import make_dataset, train_linear
from repro.quant import PrecisionPlan
from repro.core.quantize import stochastic_quantize

key = jax.random.PRNGKey(0)

# --- 1. unbiased quantization, biased naive gradients ----------------------
v = jax.random.normal(key, (8,))
qs = jax.vmap(lambda k: stochastic_quantize(v, 3, k))(jax.random.split(key, 4000))
print("E[Q(v)] - v   =", np.round(np.asarray(qs.mean(0) - v), 4), "(≈0: unbiased)")

a = jax.random.normal(key, (16, 32))
x = jax.random.normal(jax.random.fold_in(key, 1), (32,)) * 2
b = jax.random.normal(jax.random.fold_in(key, 2), (16,))
g_true = lsq_gradient_fullprec(x, a, b)
ks = jax.random.split(key, 4000)
g_naive = jax.vmap(lambda k: lsq_gradient_naive_quant(x, a, b, 3, k))(ks).mean(0)
g_ds = jax.vmap(lambda k: lsq_gradient_double_sampling(x, a, b, 3, k))(ks).mean(0)
print(f"naive-quant gradient bias   : {float(jnp.linalg.norm(g_naive - g_true)):.4f}")
print(f"double-sampling gradient bias: {float(jnp.linalg.norm(g_ds - g_true)):.4f}")

# --- 2. end-to-end low-precision training -----------------------------------
ds = make_dataset("synthetic100", n_train=2000, n_test=500)
full = train_linear(ds, PrecisionPlan("full"), epochs=8, lr=0.3)
low = train_linear(ds, PrecisionPlan("e2e", sample_bits=6, model_bits=8,
                                     grad_bits=8), epochs=8, lr=0.3)
print(f"\nfp32 loss={full.losses[-1]:.5f}   e2e 6/8/8-bit loss={low.losses[-1]:.5f}")

# --- 3. optimal quantization levels -----------------------------------------
data = np.clip(np.random.default_rng(0).beta(0.6, 3.0, 3000), 0, 1)
for s in (3, 7):
    mv_u = optimal.mean_variance(data, optimal.uniform_levels(s))
    mv_o = optimal.mean_variance(data, optimal.optimal_levels_discretized(data, s))
    print(f"s={s}: uniform MV={mv_u:.2e}  optimal MV={mv_o:.2e} "
          f"({mv_u / mv_o:.2f}× lower variance)")
