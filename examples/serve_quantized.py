"""Batched serving demo: prefill + greedy decode with int8 weights at rest
(optimal-level codes) and an int8 KV cache — the ZipML serving channels,
driven by the one four-channel :class:`repro.quant.PrecisionPlan`.

Run: PYTHONPATH=src python examples/serve_quantized.py
"""
from repro.launch.serve import serve
from repro.quant import PrecisionPlan

PLANS = (
    ("bf16 baseline", PrecisionPlan()),
    ("int8 w (uniform levels) + int8 KV",
     PrecisionPlan(model_bits=8, model_storage="int", kv_bits=8)),
    ("int8 w (optimal levels) + int8 KV",
     PrecisionPlan(model_bits=8, model_storage="int", kv_bits=8,
                   optimal_levels=True)),
)

for label, plan in PLANS:
    tokens, tps = serve("granite-3-8b", reduced=True, batch=4, prompt_len=32,
                        gen=16, plan=plan)
    print(f"{label:42s}: {tokens.shape} tokens, {tps:7.1f} tok/s")
