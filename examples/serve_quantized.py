"""Batched serving demo: prefill + greedy decode with int8 weights at rest
(optimal-level codes) and an int8 KV cache — the ZipML serving channels.

Run: PYTHONPATH=src python examples/serve_quantized.py
"""
from repro.launch.serve import serve

for kv_bits, w_bits, opt in ((0, 0, False), (8, 8, False), (8, 8, True)):
    tokens, tps = serve("granite-3-8b", reduced=True, batch=4, prompt_len=32,
                        gen=16, kv_bits=kv_bits, weight_bits=w_bits,
                        optimal_levels=opt)
    label = ("bf16 baseline" if not w_bits else
             f"int8 w ({'optimal' if opt else 'uniform'} levels) + int{kv_bits} KV")
    print(f"{label:42s}: {tokens.shape} tokens, {tps:7.1f} tok/s")
